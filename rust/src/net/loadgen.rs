//! Open-loop load generator for the serving socket (and, for matched
//! comparisons, the in-process path).
//!
//! **Open-loop means the schedule never waits for the server.** Arrival
//! times are fixed up front — request `i` fires at `i / rate_rps`
//! seconds, with its priority class drawn from a seeded RNG — and the
//! sender fires each one at its scheduled instant whether or not earlier
//! responses have come back. A closed-loop generator (send → wait →
//! send) throttles itself exactly when the server saturates, hiding the
//! queueing collapse this harness exists to measure.
//!
//! Latency is measured from the **scheduled** arrival, not the actual
//! write: if the sender itself falls behind (it shouldn't — writes are
//! fire-and-forget), that delay is server-induced queueing from the
//! client's point of view and must count (the coordinated-omission
//! trap). Tails are reported per class as p50/p99/p999 over exact
//! samples ([`Summary`]), alongside achieved-vs-offered rate and
//! shed/expired/rejected counts.
//!
//! [`run_open_loop`] drives a [`NetServer`](crate::net::NetServer) over
//! TCP; [`run_open_loop_local`] replays the *identical* schedule (same
//! seed → same arrivals, classes, deadlines) straight into a
//! [`ServingService`], so "what does the socket cost" is a like-for-like
//! subtraction — the net latency bench holds the two reports side by
//! side.

use std::collections::HashMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::wire::{read_frame, write_frame, Frame, ReadEvent, RequestFrame, WireStatus};
use crate::backend::Value;
use crate::coordinator::{Priority, ServingService, SubmitOptions, Ticket};
use crate::util::rng::Xoshiro256;
use crate::util::stats::Summary;

/// One load experiment: what to offer, at what rate, with what mix.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// model name every request targets
    pub model: String,
    /// token payload per request (sample-shaped)
    pub tokens: Vec<i32>,
    /// offered arrival rate, requests/second (across all connections)
    pub rate_rps: f64,
    /// schedule length; `rate_rps * duration` arrivals total
    pub duration: Duration,
    /// connections the schedule is striped across (round-robin)
    pub connections: usize,
    /// class mix weights, indexed by [`Priority::idx`]; normalized
    /// internally (e.g. `[0.2, 0.5, 0.3]`)
    pub mix: [f64; 3],
    /// per-class deadline attached to each request, by [`Priority::idx`]
    pub deadlines: [Option<Duration>; 3],
    /// after the last scheduled send, how long to keep collecting
    /// responses before declaring the stragglers lost
    pub drain_grace: Duration,
    /// RNG seed for the class draw — same seed, same schedule, so socket
    /// and in-process runs are compared on identical traffic
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            model: "bert_tiny".into(),
            tokens: vec![7; 32],
            rate_rps: 100.0,
            duration: Duration::from_secs(1),
            connections: 1,
            mix: [0.2, 0.5, 0.3],
            deadlines: [None, None, None],
            drain_grace: Duration::from_secs(5),
            seed: 0x54_4E45_54,
        }
    }
}

/// One precomputed arrival: when (offset from run start) and what class.
#[derive(Clone, Copy, Debug)]
struct Arrival {
    offset: Duration,
    class: Priority,
}

/// Build the full arrival schedule for a spec — deterministic in
/// `seed`, uniform spacing at `rate_rps`.
fn schedule(spec: &LoadSpec) -> Vec<Arrival> {
    assert!(spec.rate_rps > 0.0, "rate_rps must be positive");
    let total = (spec.rate_rps * spec.duration.as_secs_f64()).round() as usize;
    let weight: f64 = spec.mix.iter().sum();
    assert!(weight > 0.0, "class mix must have positive weight");
    let mut rng = Xoshiro256::seed_from_u64(spec.seed);
    (0..total)
        .map(|i| {
            let offset = Duration::from_secs_f64(i as f64 / spec.rate_rps);
            let mut draw = rng.next_f64() * weight;
            let mut class = Priority::Bulk;
            for p in Priority::ALL {
                draw -= spec.mix[p.idx()];
                if draw < 0.0 {
                    class = p;
                    break;
                }
            }
            Arrival { offset, class }
        })
        .collect()
}

/// Per-class accumulator a connection records into while running.
#[derive(Default)]
struct ClassAcc {
    offered: u64,
    completed: u64,
    expired: u64,
    cancelled: u64,
    rejected: u64,
    errors: u64,
    /// latency of each Ok response, µs from *scheduled* arrival
    latencies_us: Vec<f64>,
}

impl ClassAcc {
    fn record_status(&mut self, status: &WireStatus, latency_us: f64) {
        match status {
            WireStatus::Ok => {
                self.completed += 1;
                self.latencies_us.push(latency_us);
            }
            WireStatus::Expired => self.expired += 1,
            WireStatus::Cancelled => self.cancelled += 1,
            WireStatus::Rejected(_) => self.rejected += 1,
            WireStatus::Error(_) => self.errors += 1,
        }
    }

    fn merge(&mut self, other: ClassAcc) {
        self.offered += other.offered;
        self.completed += other.completed;
        self.expired += other.expired;
        self.cancelled += other.cancelled;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.latencies_us.extend(other.latencies_us);
    }
}

/// Final per-class figures for one run.
#[derive(Clone, Debug, Default)]
pub struct ClassLoad {
    pub offered: u64,
    pub completed: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub rejected: u64,
    pub errors: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
}

impl ClassLoad {
    fn from_acc(acc: &ClassAcc) -> ClassLoad {
        let (mean, p50, p99, p999) = if acc.latencies_us.is_empty() {
            (0.0, 0.0, 0.0, 0.0)
        } else {
            let s = Summary::of(&acc.latencies_us);
            (s.mean, s.p50, s.p99, s.p999)
        };
        ClassLoad {
            offered: acc.offered,
            completed: acc.completed,
            expired: acc.expired,
            cancelled: acc.cancelled,
            rejected: acc.rejected,
            errors: acc.errors,
            mean_us: mean,
            p50_us: p50,
            p99_us: p99,
            p999_us: p999,
        }
    }
}

/// Outcome of one open-loop run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// indexed by [`Priority::idx`]
    pub by_class: [ClassLoad; 3],
    /// the rate the spec asked for
    pub offered_rps: f64,
    /// Ok completions per second of wall time (run start → last
    /// response) — diverges from `offered_rps` past saturation
    pub achieved_rps: f64,
    pub wall_s: f64,
    /// requests actually written/submitted
    pub sent: u64,
    /// requests with no terminal answer by the end of the drain grace
    pub lost: u64,
}

impl LoadReport {
    pub fn completed(&self) -> u64 {
        self.by_class.iter().map(|c| c.completed).sum()
    }

    pub fn shed(&self) -> u64 {
        self.by_class.iter().map(|c| c.expired + c.cancelled + c.rejected).sum()
    }

    pub fn class(&self, p: Priority) -> &ClassLoad {
        &self.by_class[p.idx()]
    }

    pub fn print(&self) {
        println!(
            "open-loop: offered {:.0} rps, achieved {:.0} rps over {:.2}s \
             (sent {}, completed {}, shed {}, lost {})",
            self.offered_rps,
            self.achieved_rps,
            self.wall_s,
            self.sent,
            self.completed(),
            self.shed(),
            self.lost
        );
        for p in Priority::ALL {
            let c = self.class(p);
            if c.offered == 0 {
                continue;
            }
            println!(
                "  {:>11}: offered {:>6}  ok {:>6}  exp {:>4}  rej {:>4}  err {:>3}  \
                 p50 {:>8.0}µs  p99 {:>8.0}µs  p999 {:>8.0}µs",
                p.as_str(),
                c.offered,
                c.completed,
                c.expired,
                c.rejected,
                c.errors,
                c.p50_us,
                c.p99_us,
                c.p999_us
            );
        }
    }
}

fn finish_report(
    accs: [ClassAcc; 3],
    spec: &LoadSpec,
    sent: u64,
    lost: u64,
    wall: Duration,
) -> LoadReport {
    let by_class = [
        ClassLoad::from_acc(&accs[0]),
        ClassLoad::from_acc(&accs[1]),
        ClassLoad::from_acc(&accs[2]),
    ];
    let wall_s = wall.as_secs_f64().max(spec.duration.as_secs_f64());
    let completed: u64 = by_class.iter().map(|c| c.completed).sum();
    LoadReport {
        by_class,
        offered_rps: spec.rate_rps,
        achieved_rps: completed as f64 / wall_s,
        wall_s,
        sent,
        lost,
    }
}

fn opts_for(spec: &LoadSpec, class: Priority) -> SubmitOptions {
    let mut o = SubmitOptions::default().with_priority(class);
    if let Some(d) = spec.deadlines[class.idx()] {
        o = o.with_deadline(d);
    }
    o
}

/// Drive a [`NetServer`](crate::net::NetServer) at `addr` with the
/// spec's open-loop schedule and collect per-class latency/outcome
/// figures. Blocks until the schedule finishes and responses drain (or
/// the grace period gives up on stragglers).
pub fn run_open_loop(addr: impl ToSocketAddrs, spec: &LoadSpec) -> anyhow::Result<LoadReport> {
    let addr = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("load target resolved to no address"))?;
    let arrivals = schedule(spec);
    let conns = spec.connections.max(1);
    // stripe the schedule round-robin so each connection still fires at
    // uniform offsets
    let mut per_conn: Vec<Vec<Arrival>> = vec![Vec::new(); conns];
    for (i, a) in arrivals.iter().enumerate() {
        per_conn[i % conns].push(*a);
    }

    let start = Instant::now();
    let mut workers = Vec::new();
    for plan in per_conn {
        let spec = spec.clone();
        workers.push(
            std::thread::Builder::new()
                .name("s4-loadgen-conn".into())
                .spawn(move || conn_worker(addr, &spec, plan, start))
                .expect("spawn loadgen connection"),
        );
    }

    let mut accs: [ClassAcc; 3] = Default::default();
    let mut sent = 0u64;
    let mut lost = 0u64;
    let mut last_resp = start;
    for w in workers {
        let out = w.join().map_err(|_| anyhow::anyhow!("loadgen connection panicked"))?;
        let out = out?;
        for (dst, src) in accs.iter_mut().zip(out.accs) {
            dst.merge(src);
        }
        sent += out.sent;
        lost += out.lost;
        last_resp = last_resp.max(out.last_resp);
    }
    Ok(finish_report(accs, spec, sent, lost, last_resp - start))
}

/// What one socket connection hands back to the aggregator.
struct ConnOutcome {
    accs: [ClassAcc; 3],
    sent: u64,
    lost: u64,
    last_resp: Instant,
}

fn conn_worker(
    addr: std::net::SocketAddr,
    spec: &LoadSpec,
    plan: Vec<Arrival>,
    start: Instant,
) -> anyhow::Result<ConnOutcome> {
    let stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(Duration::from_millis(50)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;

    // id → (scheduled arrival, class); inserted BEFORE the write so the
    // reader can never see a response for an unknown id
    let inflight: Arc<Mutex<HashMap<u64, (Instant, Priority)>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let sender_done = Arc::new(AtomicBool::new(false));

    let reader_thread = {
        let inflight = inflight.clone();
        let sender_done = sender_done.clone();
        let grace = spec.drain_grace;
        let mut reader = stream;
        std::thread::Builder::new()
            .name("s4-loadgen-read".into())
            .spawn(move || -> ([ClassAcc; 3], Instant) {
                let mut accs: [ClassAcc; 3] = Default::default();
                let mut last_resp = start;
                let mut drain_deadline: Option<Instant> = None;
                loop {
                    if sender_done.load(Ordering::Acquire) {
                        let dl = *drain_deadline.get_or_insert_with(|| Instant::now() + grace);
                        if inflight.lock().unwrap().is_empty() {
                            break;
                        }
                        if Instant::now() >= dl {
                            break; // leftovers counted as lost below
                        }
                    }
                    match read_frame(&mut reader) {
                        Ok(ReadEvent::Idle) => continue,
                        Ok(ReadEvent::Closed) => break,
                        Ok(ReadEvent::Frame(Frame::Response(r))) => {
                            let now = Instant::now();
                            let sched = inflight.lock().unwrap().remove(&r.id);
                            if let Some((sched_at, class)) = sched {
                                // from the *scheduled* arrival — queueing
                                // the sender suffered counts too
                                let us = now.duration_since(sched_at).as_micros() as f64;
                                accs[class.idx()].record_status(&r.status, us);
                                last_resp = now;
                            }
                        }
                        // a request frame from the server, or a transport
                        // error: this connection is done collecting
                        Ok(ReadEvent::Frame(Frame::Request(_))) => break,
                        Err(_) => break,
                    }
                }
                (accs, last_resp)
            })
            .expect("spawn loadgen reader")
    };

    // open-loop sender: fire at each scheduled offset, never waiting for
    // responses; a send error stops this connection's schedule
    let mut sent = 0u64;
    let mut next_id = 1u64;
    for a in &plan {
        let target = start + a.offset;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let opts = opts_for(spec, a.class);
        let id = next_id;
        next_id += 1;
        inflight.lock().unwrap().insert(id, (target, a.class));
        let frame = Frame::Request(RequestFrame {
            id,
            model: spec.model.clone(),
            priority: a.class,
            deadline: opts.deadline,
            client_tag: None,
            inputs: vec![Value::tokens(spec.tokens.clone())],
        });
        if write_frame(&mut writer, &frame).is_err() {
            // connection died mid-run: schedule entries never written
            // surface through `lost` (plan - sent) below
            inflight.lock().unwrap().remove(&id);
            break;
        }
        sent += 1;
    }
    sender_done.store(true, Ordering::Release);

    let (mut accs, last_resp) =
        reader_thread.join().map_err(|_| anyhow::anyhow!("loadgen reader panicked"))?;
    // every scheduled arrival is "offered", answered or not
    for a in &plan {
        accs[a.class.idx()].offered += 1;
    }
    let leftovers = inflight.lock().unwrap().len() as u64;
    Ok(ConnOutcome { accs, sent, lost: leftovers + (plan.len() as u64 - sent), last_resp })
}

/// Replay the same open-loop schedule **in process** against a
/// [`ServingService`] — the socket-free baseline the net bench compares
/// against. Same seed ⇒ identical arrivals, classes, and deadlines as
/// [`run_open_loop`].
pub fn run_open_loop_local<S>(svc: &Arc<S>, spec: &LoadSpec) -> anyhow::Result<LoadReport>
where
    S: ServingService + Send + Sync + 'static,
{
    let arrivals = schedule(spec);
    let start = Instant::now();

    // poller thread: polls tickets like the net reply pump does, so the
    // two paths measure the same completion discipline
    let (tx, rx) = channel::<(Ticket, Instant, Priority)>();
    let grace = spec.drain_grace;
    let poller = std::thread::Builder::new()
        .name("s4-loadgen-poll".into())
        .spawn(move || -> ([ClassAcc; 3], Instant, u64) {
            let mut accs: [ClassAcc; 3] = Default::default();
            let mut pending: Vec<(Ticket, Instant, Priority)> = Vec::new();
            let mut last_resp = start;
            let mut open = true;
            let mut drain_deadline: Option<Instant> = None;
            loop {
                while open {
                    match rx.try_recv() {
                        Ok(item) => pending.push(item),
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            drain_deadline = Some(Instant::now() + grace);
                        }
                    }
                }
                let mut i = 0;
                while i < pending.len() {
                    let (ticket, sched_at, class) = &pending[i];
                    match ticket.try_take() {
                        Ok(None) => i += 1,
                        Ok(Some(resp)) => {
                            let now = Instant::now();
                            let us = now.duration_since(*sched_at).as_micros() as f64;
                            accs[class.idx()]
                                .record_status(&WireStatus::from_status(&resp.status), us);
                            last_resp = now;
                            pending.swap_remove(i);
                        }
                        Err(_) => {
                            accs[class.idx()].errors += 1;
                            pending.swap_remove(i);
                        }
                    }
                }
                if !open && pending.is_empty() {
                    break;
                }
                if let Some(dl) = drain_deadline {
                    if Instant::now() >= dl {
                        break;
                    }
                }
                if pending.is_empty() && open {
                    match rx.recv_timeout(Duration::from_millis(20)) {
                        Ok(item) => pending.push(item),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            drain_deadline = Some(Instant::now() + grace);
                        }
                    }
                } else {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            (accs, last_resp, pending.len() as u64)
        })
        .expect("spawn local load poller");

    let mut sent = 0u64;
    let mut rejected_by_class = [0u64; 3];
    for a in &arrivals {
        let target = start + a.offset;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        match svc.submit_with(&spec.model, vec![Value::tokens(spec.tokens.clone())], opts_for(spec, a.class)) {
            Ok(ticket) => {
                sent += 1;
                if tx.send((ticket, target, a.class)).is_err() {
                    break;
                }
            }
            Err(_) => {
                sent += 1;
                rejected_by_class[a.class.idx()] += 1;
            }
        }
    }
    drop(tx);
    let (mut accs, last_resp, lost) =
        poller.join().map_err(|_| anyhow::anyhow!("load poller panicked"))?;
    for a in &arrivals {
        accs[a.class.idx()].offered += 1;
    }
    for (acc, rej) in accs.iter_mut().zip(rejected_by_class) {
        acc.rejected += rej;
    }
    Ok(finish_report(accs, spec, sent, lost, last_resp - start))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_uniform_and_mix_weighted() {
        let spec = LoadSpec {
            rate_rps: 1000.0,
            duration: Duration::from_secs(2),
            mix: [0.25, 0.5, 0.25],
            seed: 42,
            ..LoadSpec::default()
        };
        let a = schedule(&spec);
        let b = schedule(&spec);
        assert_eq!(a.len(), 2000);
        // deterministic: same seed, same classes and offsets
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.offset, y.offset);
            assert_eq!(x.class, y.class);
        }
        // uniform spacing at exactly 1/rate
        assert_eq!(a[0].offset, Duration::ZERO);
        assert_eq!(a[1000].offset, Duration::from_secs(1));
        // mix roughly honoured (±10 points at n=2000)
        let mut counts = [0usize; 3];
        for x in &a {
            counts[x.class.idx()] += 1;
        }
        let frac = |i: usize| counts[i] as f64 / a.len() as f64;
        assert!((frac(0) - 0.25).abs() < 0.1, "interactive frac {}", frac(0));
        assert!((frac(1) - 0.5).abs() < 0.1, "standard frac {}", frac(1));
        // different seed, different draw
        let c = schedule(&LoadSpec { seed: 43, ..spec });
        assert!(a.iter().zip(&c).any(|(x, y)| x.class != y.class));
    }

    #[test]
    fn zero_weight_classes_never_appear() {
        let spec = LoadSpec {
            rate_rps: 500.0,
            duration: Duration::from_secs(1),
            mix: [1.0, 0.0, 0.0],
            ..LoadSpec::default()
        };
        assert!(schedule(&spec).iter().all(|a| a.class == Priority::Interactive));
    }

    #[test]
    fn class_acc_records_each_status_where_it_belongs() {
        let mut acc = ClassAcc::default();
        acc.record_status(&WireStatus::Ok, 100.0);
        acc.record_status(&WireStatus::Ok, 300.0);
        acc.record_status(&WireStatus::Expired, 0.0);
        acc.record_status(&WireStatus::Rejected("full".into()), 0.0);
        acc.record_status(&WireStatus::Error("x".into()), 0.0);
        acc.record_status(&WireStatus::Cancelled, 0.0);
        assert_eq!(acc.completed, 2);
        assert_eq!(acc.expired, 1);
        assert_eq!(acc.rejected, 1);
        assert_eq!(acc.errors, 1);
        assert_eq!(acc.cancelled, 1);
        // only Ok responses contribute latency samples
        assert_eq!(acc.latencies_us, vec![100.0, 300.0]);
        let c = ClassLoad::from_acc(&acc);
        assert_eq!(c.completed, 2);
        assert_eq!(c.p50_us, 200.0);
    }

    #[test]
    fn report_aggregates_across_classes() {
        let mut accs: [ClassAcc; 3] = Default::default();
        accs[0].offered = 10;
        accs[0].completed = 9;
        accs[0].rejected = 1;
        accs[0].latencies_us = (1..=9).map(|i| i as f64 * 100.0).collect();
        accs[2].offered = 5;
        accs[2].expired = 5;
        let spec = LoadSpec { rate_rps: 15.0, duration: Duration::from_secs(1), ..Default::default() };
        let r = finish_report(accs, &spec, 15, 0, Duration::from_secs(1));
        assert_eq!(r.completed(), 9);
        assert_eq!(r.shed(), 6);
        assert_eq!(r.class(Priority::Interactive).offered, 10);
        assert_eq!(r.class(Priority::Bulk).expired, 5);
        assert!((r.achieved_rps - 9.0).abs() < 1e-9);
        // empty class reports zeroed percentiles rather than panicking
        assert_eq!(r.class(Priority::Standard).p999_us, 0.0);
        r.print();
    }
}
