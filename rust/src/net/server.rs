//! TCP front end over any [`ServingService`] — the socket boundary of
//! the serving stack.
//!
//! Thread shape (std only, no async runtime in this environment): one
//! acceptor thread owns the listener; each connection gets exactly two
//! threads, a **reader** and a **reply pump**, so the thread count is
//! bounded by `2 × max_connections` regardless of how many requests a
//! client pipelines:
//!
//! * the reader decodes request frames and submits them through
//!   [`ServingService::submit_with`] (admission happens there, exactly
//!   as for in-process callers), handing the returned [`Ticket`] to the
//!   pump — it never blocks on a response, so a client can keep dozens
//!   of requests in flight on one connection;
//! * the pump polls its pending tickets ([`Ticket::try_take`]) and
//!   writes each response frame the moment it resolves — **out of
//!   order** when the coordinator finishes them out of order (an
//!   Interactive reply overtakes a queued Bulk one on the same socket),
//!   which is why frames carry correlation ids.
//!
//! Failure containment: a malformed frame (bad magic, garbage payload,
//! oversized length) gets a best-effort rejection frame and closes
//! **that connection only**; a panic inside the service is caught per
//! frame, answered as an error frame, and the connection keeps serving
//! (the admission slot is freed by the coordinator's worker-side
//! completion, so a panicking handler cannot leak capacity).
//!
//! Shutdown drains: [`NetServer::shutdown`] stops the acceptor, lets
//! every reader finish its current frame, and the pumps keep polling
//! until all in-flight tickets resolve (bounded by
//! [`NetServerConfig::drain_timeout`]). Wire it as a coordinator drain
//! hook — `srv.on_shutdown(move || net.shutdown())` — so the flush
//! happens while the coordinator is still answering tickets.
//!
//! Connection/frame counters land in the service's own
//! [`Metrics`] sink (via [`ServingService::shared_metrics`]) so one
//! [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot) covers both
//! the wire boundary and serving.

use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::wire::{
    read_frame, write_frame, Frame, ReadEvent, RequestFrame, ResponseFrame, WireError, WireStatus,
};
use crate::coordinator::{AdmissionDecision, Metrics, Response, ServingService, Ticket};

#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// concurrent connections; one past this is answered with a
    /// rejection frame and closed immediately
    pub max_connections: usize,
    /// reader idle tick: how long a blocking read waits before checking
    /// the stop flag (also the latency bound on noticing a shutdown)
    pub read_timeout: Duration,
    /// per-frame write budget; a peer that stops reading loses its
    /// connection rather than wedging the pump
    pub write_timeout: Duration,
    /// pump polling cadence while tickets are pending
    pub poll_interval: Duration,
    /// after the reader stops, how long the pump keeps polling
    /// unresolved tickets before abandoning the drain
    pub drain_timeout: Duration,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            max_connections: 64,
            read_timeout: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
            poll_interval: Duration::from_micros(200),
            drain_timeout: Duration::from_secs(30),
        }
    }
}

/// What the reader hands the reply pump for one decoded frame.
enum PumpItem {
    /// an admitted request: poll the ticket, reply when it resolves
    Pending { id: u64, ticket: Ticket, received: Instant },
    /// an already-decided outcome (admission rejection, handler panic,
    /// malformed-frame notice): write it on the next pump pass
    Immediate(ResponseFrame),
}

/// A running TCP front end; bind with [`NetServer::bind`], stop with
/// [`shutdown`](NetServer::shutdown) (idempotent, also runs on drop).
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    shut: AtomicBool,
    metrics: Arc<Metrics>,
}

impl NetServer {
    /// Bind `addr` (use port 0 to let the OS pick — then
    /// [`local_addr`](NetServer::local_addr) reports the real port, so
    /// tests never race on fixed ports) and start accepting.
    pub fn bind<S>(
        addr: impl ToSocketAddrs,
        svc: Arc<S>,
        cfg: NetServerConfig,
    ) -> anyhow::Result<NetServer>
    where
        S: ServingService + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        // record into the service's own sink when it has one, so net and
        // serving counters appear in the same snapshot/report
        let metrics = svc.shared_metrics().unwrap_or_else(|| Arc::new(Metrics::new()));
        let stop = Arc::new(AtomicBool::new(false));

        let acceptor = {
            let stop = stop.clone();
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("s4-net-acceptor".into())
                .spawn(move || accept_loop(listener, svc, metrics, stop, cfg))
                .expect("spawn net acceptor")
        };
        Ok(NetServer {
            local_addr,
            stop,
            acceptor: Mutex::new(Some(acceptor)),
            shut: AtomicBool::new(false),
            metrics,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The metrics sink this front end records into — the service's own
    /// sink when it exposes one, otherwise a private fallback.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Stop accepting, let each connection drain its in-flight tickets,
    /// and join all threads. Idempotent; callable from a coordinator
    /// drain hook (`&self`, no consumption).
    pub fn shutdown(&self) {
        if self.shut.swap(true, Ordering::AcqRel) {
            return;
        }
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.acceptor.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop<S>(
    listener: TcpListener,
    svc: Arc<S>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    cfg: NetServerConfig,
) where
    S: ServingService + Send + Sync + 'static,
{
    let active = Arc::new(AtomicUsize::new(0));
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    let mut conn_seq = 0u64;
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                conns.retain(|h| !h.is_finished());
                metrics.record_conn_accepted();
                if active.load(Ordering::Acquire) >= cfg.max_connections {
                    // over capacity: tell the peer why, then close; the
                    // listener itself keeps running
                    let _ = write_frame(
                        &mut stream,
                        &Frame::Response(ResponseFrame::rejected(
                            0,
                            "server at connection capacity",
                        )),
                    );
                    metrics.record_conn_closed(true);
                    continue;
                }
                active.fetch_add(1, Ordering::AcqRel);
                conn_seq += 1;
                let svc = svc.clone();
                let metrics = metrics.clone();
                let stop = stop.clone();
                let cfg = cfg.clone();
                let active = active.clone();
                conns.push(
                    std::thread::Builder::new()
                        .name(format!("s4-net-conn{conn_seq}"))
                        .spawn(move || {
                            // pump-side write failures count as error
                            // closes even though the reader then sees a
                            // clean local shutdown
                            let pump_err = Arc::new(AtomicBool::new(false));
                            let res = catch_unwind(AssertUnwindSafe(|| {
                                handle_conn(stream, &svc, &metrics, &stop, &cfg, &pump_err)
                            }));
                            let on_error = match res {
                                Ok(Ok(())) => pump_err.load(Ordering::Acquire),
                                Ok(Err(_)) => true,
                                // a handler panic must not leak the
                                // connection's accounting either
                                Err(_) => true,
                            };
                            metrics.record_conn_closed(on_error);
                            active.fetch_sub(1, Ordering::AcqRel);
                        })
                        .expect("spawn net connection handler"),
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                // transient accept failure (EMFILE, aborted handshake):
                // back off and keep listening
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

fn reject_reason(d: &AdmissionDecision) -> String {
    match d {
        AdmissionDecision::Admit => "admitted".into(), // unreachable on the Err path
        AdmissionDecision::RejectQueueFull(p) => format!("queue full ({})", p.as_str()),
        AdmissionDecision::RejectRateLimited(p) => format!("rate limited ({})", p.as_str()),
        AdmissionDecision::RejectUnhealthy(p) => {
            format!("backend unhealthy (retryable, {})", p.as_str())
        }
    }
}

/// One connection's reader loop: decode frames, submit, hand tickets to
/// the pump. `Ok(())` is a clean close (peer hung up or server stop);
/// `Err` closes this connection with an error — never the listener.
fn handle_conn<S: ServingService>(
    stream: TcpStream,
    svc: &Arc<S>,
    metrics: &Arc<Metrics>,
    stop: &Arc<AtomicBool>,
    cfg: &NetServerConfig,
    pump_err: &Arc<AtomicBool>,
) -> Result<(), WireError> {
    // accepted sockets don't reliably inherit blocking mode from the
    // nonblocking listener — force it before installing timeouts
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(cfg.read_timeout))?;
    stream.set_write_timeout(Some(cfg.write_timeout))?;
    let _ = stream.set_nodelay(true);

    let writer = stream.try_clone()?;
    let (ptx, prx) = channel::<PumpItem>();
    let pump = {
        let metrics = metrics.clone();
        let cfg = cfg.clone();
        let pump_err = pump_err.clone();
        std::thread::Builder::new()
            .name("s4-net-pump".into())
            .spawn(move || pump_loop(writer, prx, metrics, cfg, pump_err))
            .expect("spawn net reply pump")
    };

    let mut reader = BufReader::new(stream);
    let result = loop {
        if stop.load(Ordering::Acquire) {
            break Ok(());
        }
        match read_frame(&mut reader) {
            Ok(ReadEvent::Idle) => continue,
            Ok(ReadEvent::Closed) => break Ok(()),
            Ok(ReadEvent::Frame(Frame::Request(rf))) => {
                metrics.record_frame_in();
                let received = Instant::now();
                let RequestFrame { id, model, inputs, .. } = &rf;
                let opts = rf.options();
                let (model, inputs) = (model.clone(), inputs.clone());
                // panic fence: a service that panics mid-submit answers
                // this frame as an error and keeps the connection (and
                // listener) alive; if the inner submission had already
                // been admitted, the coordinator's worker still answers
                // the dropped ticket and completes the admission slot
                let outcome =
                    catch_unwind(AssertUnwindSafe(|| svc.submit_with(&model, inputs, opts)));
                let item = match outcome {
                    Ok(Ok(ticket)) => PumpItem::Pending { id: *id, ticket, received },
                    Ok(Err(decision)) => {
                        PumpItem::Immediate(ResponseFrame::rejected(*id, reject_reason(&decision)))
                    }
                    Err(_) => PumpItem::Immediate(ResponseFrame {
                        id: *id,
                        status: WireStatus::Error("internal error: handler panicked".into()),
                        ..ResponseFrame::rejected(*id, "")
                    }),
                };
                if ptx.send(item).is_err() {
                    // pump exited (write failure); it already flagged the
                    // error and shut the socket down
                    break Ok(());
                }
            }
            Ok(ReadEvent::Frame(Frame::Response(_))) => {
                metrics.record_malformed_frame();
                let _ = ptx.send(PumpItem::Immediate(ResponseFrame::rejected(
                    0,
                    "protocol error: client sent a response frame",
                )));
                break Err(WireError::Malformed("client sent a response frame".into()));
            }
            Err(e @ (WireError::Malformed(_) | WireError::TooLarge(_))) => {
                metrics.record_malformed_frame();
                // best-effort: tell the peer why before hanging up on it
                let _ = ptx
                    .send(PumpItem::Immediate(ResponseFrame::rejected(0, e.to_string())));
                break Err(e);
            }
            Err(e) => break Err(e),
        }
    };
    // reader done: close the intake, then wait for the pump to flush
    // every pending ticket (bounded by drain_timeout)
    drop(ptx);
    let _ = pump.join();
    result
}

fn response_frame(id: u64, resp: Response, server_us: u64) -> ResponseFrame {
    ResponseFrame {
        id,
        status: WireStatus::from_status(&resp.status),
        outputs: resp.outputs,
        served_by: resp.served_by.to_string(),
        batch_size: resp.batch_size as u32,
        latency_us: resp.latency_us,
        queue_us: resp.queue_us,
        server_us,
    }
}

/// Reply pump: single writer for one connection. Ingests items from the
/// reader, polls pending tickets, writes responses as they resolve
/// (out of order), and drains after the reader closes the channel.
fn pump_loop(
    mut w: TcpStream,
    rx: Receiver<PumpItem>,
    metrics: Arc<Metrics>,
    cfg: NetServerConfig,
    pump_err: Arc<AtomicBool>,
) {
    let mut pending: Vec<(u64, Ticket, Instant)> = Vec::new();
    let mut open = true;
    let mut drain_deadline: Option<Instant> = None;

    let fail = |w: &TcpStream, pump_err: &AtomicBool| {
        pump_err.store(true, Ordering::Release);
        // unblock the reader (its blocking read returns 0) so the
        // connection tears down promptly instead of idling out
        let _ = w.shutdown(Shutdown::Both);
    };

    'outer: loop {
        // ingest whatever the reader has queued
        while open {
            match rx.try_recv() {
                Ok(PumpItem::Pending { id, ticket, received }) => {
                    pending.push((id, ticket, received))
                }
                Ok(PumpItem::Immediate(f)) => {
                    if write_frame(&mut w, &Frame::Response(f)).is_err() {
                        fail(&w, &pump_err);
                        break 'outer;
                    }
                    metrics.record_frame_out();
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    drain_deadline = Some(Instant::now() + cfg.drain_timeout);
                }
            }
        }
        // poll pending tickets; write each response the moment it lands
        let mut i = 0;
        while i < pending.len() {
            let (id, ticket, received) = &pending[i];
            match ticket.try_take() {
                Ok(None) => i += 1,
                Ok(Some(resp)) => {
                    let f = response_frame(*id, resp, received.elapsed().as_micros() as u64);
                    pending.swap_remove(i);
                    if write_frame(&mut w, &Frame::Response(f)).is_err() {
                        fail(&w, &pump_err);
                        break 'outer;
                    }
                    metrics.record_frame_out();
                }
                Err(e) => {
                    // coordinator torn down without answering: the peer
                    // still deserves a terminal frame for this id
                    let f = ResponseFrame {
                        id: *id,
                        status: WireStatus::Error(e.to_string()),
                        ..ResponseFrame::rejected(*id, "")
                    };
                    pending.swap_remove(i);
                    if write_frame(&mut w, &Frame::Response(f)).is_err() {
                        fail(&w, &pump_err);
                        break 'outer;
                    }
                    metrics.record_frame_out();
                }
            }
        }
        if !open && pending.is_empty() {
            break; // fully drained
        }
        if let Some(dl) = drain_deadline {
            if Instant::now() >= dl && !pending.is_empty() {
                // drain abandoned: answer what's left so the peer isn't
                // left waiting on ids that will never resolve
                for (id, _t, _r) in pending.drain(..) {
                    let f = ResponseFrame {
                        id,
                        status: WireStatus::Error("server drain timeout".into()),
                        ..ResponseFrame::rejected(id, "")
                    };
                    if write_frame(&mut w, &Frame::Response(f)).is_err() {
                        break;
                    }
                    metrics.record_frame_out();
                }
                fail(&w, &pump_err);
                break;
            }
        }
        // wait for work: block on the channel when idle, poll fast when
        // tickets are in flight
        if pending.is_empty() && open {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(PumpItem::Pending { id, ticket, received }) => {
                    pending.push((id, ticket, received))
                }
                Ok(PumpItem::Immediate(f)) => {
                    if write_frame(&mut w, &Frame::Response(f)).is_err() {
                        fail(&w, &pump_err);
                        break;
                    }
                    metrics.record_frame_out();
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    drain_deadline = Some(Instant::now() + cfg.drain_timeout);
                }
            }
        } else if !pending.is_empty() {
            std::thread::sleep(cfg.poll_interval);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Value;
    use crate::coordinator::{
        MetricsSnapshot, Priority, RequestId, ResponseStatus, SubmitOptions,
    };
    use std::sync::atomic::AtomicU64;

    /// Answers every submission instantly by echoing the inputs back —
    /// a ServingService small enough for socket-layer unit tests.
    struct InstantEcho {
        metrics: Arc<Metrics>,
        next: AtomicU64,
    }

    impl InstantEcho {
        fn new() -> Arc<InstantEcho> {
            Arc::new(InstantEcho { metrics: Arc::new(Metrics::new()), next: AtomicU64::new(1) })
        }
    }

    impl ServingService for InstantEcho {
        fn submit_with(
            &self,
            model: &str,
            inputs: Vec<Value>,
            opts: SubmitOptions,
        ) -> Result<Ticket, AdmissionDecision> {
            if model == "boom" {
                panic!("backend exploded");
            }
            if model == "full" {
                return Err(AdmissionDecision::RejectQueueFull(opts.priority));
            }
            let id = RequestId(self.next.fetch_add(1, Ordering::Relaxed));
            let (tx, rx) = channel();
            let ticket = Ticket::new(id, opts.priority, rx, Arc::new(AtomicBool::new(false)));
            tx.send(Response {
                id,
                outputs: inputs,
                served_by: Arc::from("stub_artifact"),
                batch_size: 1,
                latency_us: 7,
                queue_us: 3,
                status: ResponseStatus::Ok,
            })
            .unwrap();
            Ok(ticket)
        }

        fn metrics_snapshot(&self) -> MetricsSnapshot {
            self.metrics.snapshot()
        }

        fn shared_metrics(&self) -> Option<Arc<Metrics>> {
            Some(self.metrics.clone())
        }
    }

    fn request(id: u64, model: &str, tokens: Vec<i32>) -> Frame {
        Frame::Request(RequestFrame {
            id,
            model: model.into(),
            priority: Priority::Interactive,
            deadline: None,
            client_tag: None,
            inputs: vec![Value::tokens(tokens)],
        })
    }

    fn call(stream: &mut TcpStream, f: &Frame) -> ResponseFrame {
        write_frame(stream, f).expect("write");
        loop {
            match read_frame(stream).expect("read") {
                ReadEvent::Frame(Frame::Response(r)) => return r,
                ReadEvent::Idle => continue,
                other => panic!("expected response, got {other:?}"),
            }
        }
    }

    fn bind_echo(cfg: NetServerConfig) -> (NetServer, Arc<InstantEcho>) {
        let svc = InstantEcho::new();
        let net = NetServer::bind("127.0.0.1:0", svc.clone(), cfg).expect("bind");
        (net, svc)
    }

    #[test]
    fn binds_port_zero_and_echoes_through_the_socket() {
        let (net, svc) = bind_echo(NetServerConfig::default());
        assert_ne!(net.local_addr().port(), 0, "port 0 must resolve to a real port");
        let mut c = TcpStream::connect(net.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let r = call(&mut c, &request(41, "m", vec![1, 2, 3]));
        assert_eq!(r.id, 41);
        assert!(r.is_ok(), "{:?}", r.status);
        assert_eq!(r.outputs, vec![Value::I32(vec![1, 2, 3])]);
        assert_eq!(r.served_by, "stub_artifact");
        drop(c);
        net.shutdown();
        let s = svc.metrics.snapshot();
        assert_eq!(s.net.frames_in, 1);
        assert_eq!(s.net.frames_out, 1);
        assert_eq!(s.net.conns_accepted, 1);
        assert_eq!(s.net.conns_active, 0, "closed connection must release the gauge");
    }

    #[test]
    fn garbage_closes_only_that_connection() {
        let (net, svc) = bind_echo(NetServerConfig::default());
        let mut bad = TcpStream::connect(net.local_addr()).unwrap();
        bad.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        std::io::Write::write_all(&mut bad, b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // the server answers with a rejection frame, then closes
        match read_frame(&mut bad).expect("rejection frame") {
            ReadEvent::Frame(Frame::Response(r)) => {
                assert_eq!(r.id, 0);
                assert!(matches!(r.status, WireStatus::Rejected(_)), "{:?}", r.status);
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        let mut probe = [0u8; 1];
        loop {
            match std::io::Read::read(&mut bad, &mut probe) {
                Ok(0) => break, // closed, as promised
                Ok(_) => panic!("unexpected bytes after rejection"),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionReset | std::io::ErrorKind::BrokenPipe
                    ) =>
                {
                    break
                }
                Err(e) => panic!("probe: {e}"),
            }
        }
        // a well-behaved connection still serves
        let mut good = TcpStream::connect(net.local_addr()).unwrap();
        good.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        assert!(call(&mut good, &request(7, "m", vec![9])).is_ok());
        drop(good);
        net.shutdown();
        let s = svc.metrics.snapshot();
        assert_eq!(s.net.frames_malformed, 1);
        assert_eq!(s.net.conns_closed_on_error, 1);
        assert_eq!(s.net.conns_accepted, 2);
    }

    #[test]
    fn admission_rejection_comes_back_as_a_rejected_frame() {
        let (net, _svc) = bind_echo(NetServerConfig::default());
        let mut c = TcpStream::connect(net.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let r = call(&mut c, &request(5, "full", vec![1]));
        assert_eq!(r.id, 5);
        match &r.status {
            WireStatus::Rejected(m) => assert!(m.contains("queue full"), "{m}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        drop(c);
        net.shutdown();
    }

    #[test]
    fn handler_panic_answers_an_error_frame_and_keeps_the_connection() {
        let (net, _svc) = bind_echo(NetServerConfig::default());
        let mut c = TcpStream::connect(net.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let r = call(&mut c, &request(1, "boom", vec![1]));
        assert_eq!(r.id, 1);
        assert!(matches!(&r.status, WireStatus::Error(m) if m.contains("panic")), "{:?}", r.status);
        // same connection, next frame: still served
        let r2 = call(&mut c, &request(2, "m", vec![4]));
        assert!(r2.is_ok(), "{:?}", r2.status);
        drop(c);
        net.shutdown();
    }

    #[test]
    fn over_capacity_connection_is_refused_with_a_frame() {
        let (net, svc) = bind_echo(NetServerConfig {
            max_connections: 1,
            ..NetServerConfig::default()
        });
        let mut held = TcpStream::connect(net.local_addr()).unwrap();
        held.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        // prove the first connection's handler is up before connecting again
        assert!(call(&mut held, &request(1, "m", vec![1])).is_ok());
        let mut extra = TcpStream::connect(net.local_addr()).unwrap();
        extra.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        match read_frame(&mut extra).expect("capacity frame") {
            ReadEvent::Frame(Frame::Response(r)) => {
                assert!(
                    matches!(&r.status, WireStatus::Rejected(m) if m.contains("capacity")),
                    "{:?}",
                    r.status
                );
            }
            other => panic!("expected capacity rejection, got {other:?}"),
        }
        // the held connection is unaffected
        assert!(call(&mut held, &request(2, "m", vec![2])).is_ok());
        drop(held);
        drop(extra);
        net.shutdown();
        let s = svc.metrics.snapshot();
        assert_eq!(s.net.conns_accepted, 2);
        assert_eq!(s.net.conns_closed_on_error, 1);
    }

    #[test]
    fn shutdown_is_idempotent_and_runs_on_drop() {
        let (net, _svc) = bind_echo(NetServerConfig::default());
        let addr = net.local_addr();
        net.shutdown();
        net.shutdown(); // second call is a no-op, not a double-join
        drop(net); // drop after explicit shutdown is fine too
        // the listener is really gone: a fresh bind on the same port works
        let _relisten = TcpListener::bind(addr).expect("port released after shutdown");
    }
}
