//! Length-prefixed binary frame codec for the serving socket boundary.
//!
//! One frame on the wire is
//!
//! ```text
//! ┌────────────┬─────────┬──────────────┬─────────────────┐
//! │ magic "S4N1" │ type u8 │ payload len  │ payload         │
//! │ 4 bytes      │ 1=req   │ u32 LE       │ `len` bytes     │
//! │              │ 2=resp  │ ≤ 16 MiB     │                 │
//! └────────────┴─────────┴──────────────┴─────────────────┘
//! ```
//!
//! Request payloads carry the **full QoS submission surface** — model
//! name, [`Priority`] class, deadline, client tag (the
//! [`SubmitOptions`] fields), and typed input tensors ([`Value`], raw
//! little-endian element bytes, so an `f32` logits round trip is
//! bitwise). Response payloads carry the typed outcome ([`WireStatus`],
//! which is [`ResponseStatus`] plus wire-only `Rejected` for admission
//! refusals), output tensors, and server-side timing (coordinator
//! latency/queue plus the net layer's own decode→reply wall time).
//!
//! Anything that fails to decode — wrong magic, unknown type or dtype
//! tag, a declared length past [`MAX_FRAME_BYTES`], truncated or
//! trailing payload bytes — is a [`WireError::Malformed`] /
//! [`WireError::TooLarge`]; the server answers with a best-effort error
//! frame and closes **that connection only** (never the listener).
//! Integers are little-endian throughout; the codec allocates nothing
//! beyond the payload buffers themselves.

use std::fmt;
use std::io::{self, Read, Write};
use std::time::Duration;

use crate::backend::Value;
use crate::coordinator::{Priority, ResponseStatus, SubmitOptions};

/// Frame preamble — rejects non-protocol peers (HTTP probes, garbage)
/// on the first four bytes.
pub const MAGIC: [u8; 4] = *b"S4N1";

/// Upper bound on one frame's payload; a declared length past this is
/// rejected *before* any allocation, so a hostile header cannot OOM the
/// server.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

const TYPE_REQUEST: u8 = 1;
const TYPE_RESPONSE: u8 = 2;

const DTYPE_S32: u8 = 0;
const DTYPE_F32: u8 = 1;

/// Codec failure. `Io` is transport-level (including mid-frame timeouts
/// — once a frame has started, a stall is a broken peer); the other two
/// are protocol violations by a live peer.
#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    /// bad magic, unknown tag, truncated/trailing payload bytes, ...
    Malformed(String),
    /// declared payload length exceeds [`MAX_FRAME_BYTES`]
    TooLarge(usize),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io: {e}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
            WireError::TooLarge(n) => {
                write!(f, "frame payload {n} bytes exceeds max {MAX_FRAME_BYTES}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// One inference request as it crosses the socket: the
/// [`SubmitOptions`] surface plus typed inputs, with a client-chosen
/// correlation id echoed back on the response (responses may complete
/// out of order across priorities).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub model: String,
    pub priority: Priority,
    pub deadline: Option<Duration>,
    pub client_tag: Option<String>,
    /// one sample-shaped value per model input
    pub inputs: Vec<Value>,
}

impl RequestFrame {
    /// The in-process [`SubmitOptions`] this frame asks for.
    pub fn options(&self) -> SubmitOptions {
        let mut o = SubmitOptions::default().with_priority(self.priority);
        if let Some(d) = self.deadline {
            o = o.with_deadline(d);
        }
        if let Some(t) = &self.client_tag {
            o = o.with_client_tag(t.clone());
        }
        o
    }
}

/// Wire-level outcome: [`ResponseStatus`] plus `Rejected`, which
/// in-process is an `Err(AdmissionDecision)` *before* any ticket exists
/// and therefore has no `ResponseStatus` to map to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireStatus {
    Ok,
    Error(String),
    Expired,
    Cancelled,
    /// admission refused the request: nothing was queued or executed
    Rejected(String),
}

impl WireStatus {
    pub fn from_status(s: &ResponseStatus) -> WireStatus {
        match s {
            ResponseStatus::Ok => WireStatus::Ok,
            ResponseStatus::Error(m) => WireStatus::Error(m.clone()),
            ResponseStatus::Expired => WireStatus::Expired,
            ResponseStatus::Cancelled => WireStatus::Cancelled,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, WireStatus::Ok)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WireStatus::Ok => "ok",
            WireStatus::Error(_) => "error",
            WireStatus::Expired => "expired",
            WireStatus::Cancelled => "cancelled",
            WireStatus::Rejected(_) => "rejected",
        }
    }
}

/// One response as it crosses the socket. `latency_us`/`queue_us` are
/// the coordinator's own serving telemetry; `server_us` is the net
/// layer's wall time from frame decode to reply write — subtracting the
/// two isolates socket-side overhead without a synchronized clock.
#[derive(Clone, Debug, PartialEq)]
pub struct ResponseFrame {
    /// correlation id echoed from the request (0 when the request was
    /// too malformed to carry one)
    pub id: u64,
    pub status: WireStatus,
    /// one sample-shaped value per model output
    pub outputs: Vec<Value>,
    pub served_by: String,
    pub batch_size: u32,
    /// coordinator end-to-end latency (submit → demux), µs
    pub latency_us: u64,
    /// time queued before execution, µs
    pub queue_us: u64,
    /// net-layer wall time (frame decoded → response written), µs
    pub server_us: u64,
}

impl ResponseFrame {
    /// Unserved outcome (rejection / protocol error) for `id`.
    pub fn rejected(id: u64, reason: impl Into<String>) -> ResponseFrame {
        ResponseFrame {
            id,
            status: WireStatus::Rejected(reason.into()),
            outputs: Vec::new(),
            served_by: String::new(),
            batch_size: 0,
            latency_us: 0,
            queue_us: 0,
            server_us: 0,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.status.is_ok()
    }

    /// First f32 output — mirrors
    /// [`Response::logits`](crate::coordinator::Response::logits).
    pub fn logits(&self) -> &[f32] {
        self.outputs.iter().find_map(|v| v.as_f32()).unwrap_or(&[])
    }
}

/// A decoded frame, either direction.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Request(RequestFrame),
    Response(ResponseFrame),
}

/// Outcome of one read attempt on a connection with a read timeout set.
#[derive(Debug)]
pub enum ReadEvent {
    Frame(Frame),
    /// no bytes arrived within the read timeout — an idle poll tick, not
    /// an error (the caller checks its stop flag and reads again)
    Idle,
    /// the peer closed cleanly between frames
    Closed,
}

// ---- encoding ----

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) -> Result<(), WireError> {
    let b = s.as_bytes();
    if b.len() > u16::MAX as usize {
        return Err(WireError::Malformed(format!("string field {} bytes > u16", b.len())));
    }
    put_u16(buf, b.len() as u16);
    buf.extend_from_slice(b);
    Ok(())
}

fn put_opt_str(buf: &mut Vec<u8>, s: Option<&str>) -> Result<(), WireError> {
    match s {
        None => buf.push(0),
        Some(s) => {
            buf.push(1);
            put_str(buf, s)?;
        }
    }
    Ok(())
}

fn put_value(buf: &mut Vec<u8>, v: &Value) -> Result<(), WireError> {
    if v.len() > u32::MAX as usize {
        return Err(WireError::Malformed(format!("tensor {} elems > u32", v.len())));
    }
    match v {
        Value::I32(xs) => {
            buf.push(DTYPE_S32);
            put_u32(buf, xs.len() as u32);
            for x in xs {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Value::F32(xs) => {
            buf.push(DTYPE_F32);
            put_u32(buf, xs.len() as u32);
            for x in xs {
                // raw bit pattern: the logits round trip is bitwise
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    Ok(())
}

fn put_values(buf: &mut Vec<u8>, vs: &[Value]) -> Result<(), WireError> {
    if vs.len() > u16::MAX as usize {
        return Err(WireError::Malformed(format!("{} tensors > u16", vs.len())));
    }
    put_u16(buf, vs.len() as u16);
    for v in vs {
        put_value(buf, v)?;
    }
    Ok(())
}

/// Serialize one frame (header + payload) into a buffer ready for a
/// single `write_all`.
pub fn encode_frame(f: &Frame) -> Result<Vec<u8>, WireError> {
    let mut payload = Vec::new();
    let ty = match f {
        Frame::Request(r) => {
            put_u64(&mut payload, r.id);
            put_str(&mut payload, &r.model)?;
            payload.push(r.priority.idx() as u8);
            match r.deadline {
                None => payload.push(0),
                Some(d) => {
                    payload.push(1);
                    put_u64(&mut payload, d.as_micros() as u64);
                }
            }
            put_opt_str(&mut payload, r.client_tag.as_deref())?;
            put_values(&mut payload, &r.inputs)?;
            TYPE_REQUEST
        }
        Frame::Response(r) => {
            put_u64(&mut payload, r.id);
            let (code, msg): (u8, Option<&str>) = match &r.status {
                WireStatus::Ok => (0, None),
                WireStatus::Error(m) => (1, Some(m)),
                WireStatus::Expired => (2, None),
                WireStatus::Cancelled => (3, None),
                WireStatus::Rejected(m) => (4, Some(m)),
            };
            payload.push(code);
            put_opt_str(&mut payload, msg)?;
            put_str(&mut payload, &r.served_by)?;
            put_u32(&mut payload, r.batch_size);
            put_u64(&mut payload, r.latency_us);
            put_u64(&mut payload, r.queue_us);
            put_u64(&mut payload, r.server_us);
            put_values(&mut payload, &r.outputs)?;
            TYPE_RESPONSE
        }
    };
    if payload.len() > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge(payload.len()));
    }
    let mut out = Vec::with_capacity(MAGIC.len() + 5 + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(ty);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Encode and write one frame (single `write_all` + flush).
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), WireError> {
    let bytes = encode_frame(f)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

// ---- decoding ----

/// Bounds-checked payload cursor.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.b.len() - self.pos < n {
            return Err(WireError::Malformed(format!(
                "payload truncated: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| WireError::Malformed("string field is not UTF-8".into()))
    }

    fn opt_str(&mut self) -> Result<Option<String>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str()?)),
            t => Err(WireError::Malformed(format!("bad option tag {t}"))),
        }
    }

    fn value(&mut self) -> Result<Value, WireError> {
        let tag = self.u8()?;
        let n = self.u32()? as usize;
        // `take` bounds n*4 against the remaining payload, so a hostile
        // element count cannot drive a huge allocation
        let bytes = self.take(n * 4)?;
        Ok(match tag {
            DTYPE_S32 => Value::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DTYPE_F32 => Value::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            t => return Err(WireError::Malformed(format!("unknown dtype tag {t}"))),
        })
    }

    fn values(&mut self) -> Result<Vec<Value>, WireError> {
        let n = self.u16()? as usize;
        (0..n).map(|_| self.value()).collect()
    }

    /// Trailing payload bytes are a protocol violation, not slack.
    fn done(&self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing payload bytes",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn decode_payload(ty: u8, payload: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cur { b: payload, pos: 0 };
    let f = match ty {
        TYPE_REQUEST => {
            let id = c.u64()?;
            let model = c.str()?;
            let priority = match c.u8()? {
                0 => Priority::Interactive,
                1 => Priority::Standard,
                2 => Priority::Bulk,
                p => return Err(WireError::Malformed(format!("bad priority {p}"))),
            };
            let deadline = match c.u8()? {
                0 => None,
                1 => Some(Duration::from_micros(c.u64()?)),
                t => return Err(WireError::Malformed(format!("bad deadline tag {t}"))),
            };
            let client_tag = c.opt_str()?;
            let inputs = c.values()?;
            Frame::Request(RequestFrame { id, model, priority, deadline, client_tag, inputs })
        }
        TYPE_RESPONSE => {
            let id = c.u64()?;
            let code = c.u8()?;
            let msg = c.opt_str()?;
            let status = match (code, msg) {
                (0, None) => WireStatus::Ok,
                (1, Some(m)) => WireStatus::Error(m),
                (2, None) => WireStatus::Expired,
                (3, None) => WireStatus::Cancelled,
                (4, Some(m)) => WireStatus::Rejected(m),
                (c2, m) => {
                    return Err(WireError::Malformed(format!(
                        "bad status code {c2} (msg present: {})",
                        m.is_some()
                    )))
                }
            };
            let served_by = c.str()?;
            let batch_size = c.u32()?;
            let latency_us = c.u64()?;
            let queue_us = c.u64()?;
            let server_us = c.u64()?;
            let outputs = c.values()?;
            Frame::Response(ResponseFrame {
                id,
                status,
                outputs,
                served_by,
                batch_size,
                latency_us,
                queue_us,
                server_us,
            })
        }
        t => return Err(WireError::Malformed(format!("unknown frame type {t}"))),
    };
    c.done()?;
    Ok(f)
}

/// `read_exact` that retries `Interrupted` but treats a timeout
/// (`WouldBlock`/`TimedOut`) as an error: once a frame has started, a
/// stalled peer is a broken peer (the slow-trickle defence).
fn read_exact_frame(r: &mut impl Read, buf: &mut [u8]) -> Result<(), WireError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(WireError::Malformed(format!(
                    "peer closed mid-frame ({} of {} bytes)",
                    filled,
                    buf.len()
                )))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame from a stream whose read timeout doubles as the idle
/// poll tick. A timeout **before the first byte** is [`ReadEvent::Idle`]
/// (nothing was in flight); a clean close there is [`ReadEvent::Closed`].
/// After the first byte, truncation, stalls, and garbage are errors.
pub fn read_frame(r: &mut impl Read) -> Result<ReadEvent, WireError> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(ReadEvent::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                return Ok(ReadEvent::Idle)
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    // rest of magic (3) + type (1) + payload len (4)
    let mut hdr = [0u8; 8];
    read_exact_frame(r, &mut hdr)?;
    if first[0] != MAGIC[0] || hdr[..3] != MAGIC[1..] {
        return Err(WireError::Malformed("bad magic".into()));
    }
    let ty = hdr[3];
    let len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge(len));
    }
    let mut payload = vec![0u8; len];
    read_exact_frame(r, &mut payload)?;
    Ok(ReadEvent::Frame(decode_payload(ty, &payload)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode_frame(f).expect("encode");
        let mut cur = io::Cursor::new(bytes);
        match read_frame(&mut cur).expect("decode") {
            ReadEvent::Frame(g) => g,
            other => panic!("expected frame, got {other:?}"),
        }
    }

    fn gen_value(g: &mut Gen) -> Value {
        let n = g.usize_in(0, 64);
        if g.bool() {
            Value::I32((0..n).map(|_| g.rng.next_u64() as i32).collect())
        } else {
            // arbitrary bit patterns except NaN-breaking PartialEq: use
            // finite values spanning sign/exponent range
            Value::F32((0..n).map(|_| (g.f64_in(-1e9, 1e9)) as f32).collect())
        }
    }

    #[test]
    fn prop_request_frames_roundtrip_bitwise() {
        check("request frame roundtrip", 200, |g| {
            let inputs = (0..g.usize_in(0, 4)).map(|_| gen_value(g)).collect::<Vec<_>>();
            let f = Frame::Request(RequestFrame {
                id: g.rng.next_u64(),
                model: format!("model_{}", g.usize_in(0, 999)),
                priority: *g.pick(&Priority::ALL),
                deadline: if g.bool() {
                    Some(Duration::from_micros(g.rng.next_u64() >> 20))
                } else {
                    None
                },
                client_tag: if g.bool() { Some(format!("tag-{}", g.usize_in(0, 99))) } else { None },
                inputs,
            });
            let back = roundtrip(&f);
            crate::prop_assert!(back == f, "roundtrip drifted: {back:?} != {f:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_response_frames_roundtrip_bitwise() {
        check("response frame roundtrip", 200, |g| {
            let status = match g.usize_in(0, 4) {
                0 => WireStatus::Ok,
                1 => WireStatus::Error(format!("e{}", g.usize_in(0, 9))),
                2 => WireStatus::Expired,
                3 => WireStatus::Cancelled,
                _ => WireStatus::Rejected(format!("r{}", g.usize_in(0, 9))),
            };
            let f = Frame::Response(ResponseFrame {
                id: g.rng.next_u64(),
                status,
                outputs: (0..g.usize_in(0, 3)).map(|_| gen_value(g)).collect(),
                served_by: format!("artifact_{}", g.usize_in(0, 99)),
                batch_size: g.usize_in(0, 64) as u32,
                latency_us: g.rng.next_u64() >> 32,
                queue_us: g.rng.next_u64() >> 32,
                server_us: g.rng.next_u64() >> 32,
            });
            let back = roundtrip(&f);
            crate::prop_assert!(back == f, "roundtrip drifted: {back:?} != {f:?}");
            Ok(())
        });
    }

    #[test]
    fn f32_payloads_roundtrip_bit_exact() {
        // exact bit patterns incl. subnormals, -0.0, and ±inf
        let xs = vec![0.0f32, -0.0, f32::MIN_POSITIVE / 2.0, f32::INFINITY, -1.5e-42, 3.25];
        let f = Frame::Request(RequestFrame {
            id: 1,
            model: "m".into(),
            priority: Priority::Interactive,
            deadline: Some(Duration::from_millis(5)),
            client_tag: None,
            inputs: vec![Value::F32(xs.clone())],
        });
        let Frame::Request(r) = roundtrip(&f) else { panic!("type flipped") };
        let back = r.inputs[0].as_f32().unwrap();
        for (a, b) in back.iter().zip(&xs) {
            assert_eq!(a.to_bits(), b.to_bits(), "bitwise drift: {a} vs {b}");
        }
    }

    #[test]
    fn options_carry_the_full_submit_surface() {
        let rf = RequestFrame {
            id: 9,
            model: "bert_tiny".into(),
            priority: Priority::Bulk,
            deadline: Some(Duration::from_micros(1500)),
            client_tag: Some("cam-3".into()),
            inputs: vec![],
        };
        let o = rf.options();
        assert_eq!(o.priority, Priority::Bulk);
        assert_eq!(o.deadline, Some(Duration::from_micros(1500)));
        assert_eq!(o.client_tag.as_deref(), Some("cam-3"));
        let o = RequestFrame { deadline: None, client_tag: None, ..rf }.options();
        assert!(o.deadline.is_none() && o.client_tag.is_none());
    }

    #[test]
    fn garbage_bytes_are_malformed_not_a_panic() {
        let mut cur = io::Cursor::new(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n".to_vec());
        match read_frame(&mut cur) {
            Err(WireError::Malformed(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frames_are_rejected() {
        let full = encode_frame(&Frame::Request(RequestFrame {
            id: 3,
            model: "bert_tiny".into(),
            priority: Priority::Standard,
            deadline: None,
            client_tag: None,
            inputs: vec![Value::I32(vec![1, 2, 3])],
        }))
        .unwrap();
        // every strict prefix after the first byte must fail loudly
        for cut in 1..full.len() {
            let mut cur = io::Cursor::new(full[..cut].to_vec());
            match read_frame(&mut cur) {
                Err(WireError::Malformed(_)) | Err(WireError::Io(_)) => {}
                Ok(ReadEvent::Frame(_)) => panic!("decoded a {cut}-byte prefix"),
                other => panic!("prefix {cut}: unexpected {other:?}"),
            }
        }
        // cut == 0 is a clean close, not an error
        let mut empty = io::Cursor::new(Vec::new());
        assert!(matches!(read_frame(&mut empty).unwrap(), ReadEvent::Closed));
    }

    #[test]
    fn oversized_declared_length_is_rejected_before_allocation() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(TYPE_REQUEST);
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = io::Cursor::new(bytes);
        match read_frame(&mut cur) {
            Err(WireError::TooLarge(n)) => assert_eq!(n, u32::MAX as usize),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn hostile_element_count_is_bounded_by_payload() {
        // payload claims 2^30 f32 elems but carries 4 bytes: the cursor
        // must reject without allocating 4 GiB
        let mut payload = Vec::new();
        put_u64(&mut payload, 1); // id
        put_str(&mut payload, "m").unwrap();
        payload.push(1); // standard
        payload.push(0); // no deadline
        payload.push(0); // no tag
        put_u16(&mut payload, 1); // one input
        payload.push(DTYPE_F32);
        put_u32(&mut payload, 1 << 30);
        payload.extend_from_slice(&[0u8; 4]);
        match decode_payload(TYPE_REQUEST, &payload) {
            Err(WireError::Malformed(m)) => assert!(m.contains("truncated"), "{m}"),
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let full = encode_frame(&Frame::Response(ResponseFrame::rejected(1, "x"))).unwrap();
        let mut bytes = full.clone();
        // grow the declared length and append junk
        let len = u32::from_le_bytes(bytes[5..9].try_into().unwrap()) + 2;
        bytes[5..9].copy_from_slice(&len.to_le_bytes());
        bytes.extend_from_slice(&[0xAA, 0xBB]);
        let mut cur = io::Cursor::new(bytes);
        match read_frame(&mut cur) {
            Err(WireError::Malformed(m)) => assert!(m.contains("trailing"), "{m}"),
            other => panic!("expected malformed, got {other:?}"),
        }
    }
}
