//! Bench: roofline-guided kernel autotuning — tuned per-shape dispatch
//! plans vs the fixed default dispatch, on shapes the default handles
//! badly.
//!
//! The fixed dispatch is one point (tile 128, parallel iff `m·k ≥ 2048`)
//! on a per-shape curve; its size heuristic ignores `n`, so a small-m ×
//! wide-n layer runs serial while holding several stripes' worth of
//! compute. The sweep below includes exactly those shapes (plus one
//! saturated large-m point where tuned ≈ default, as a no-regression
//! control) and measures `default_p50 / tuned_p50` per shape.
//!
//! Emits `BENCH_autotune.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf "Autotuning"). The run **fails** unless the geomean
//! `tuned_vs_default_throughput_ratio ≥ 1.05` and no shape falls below
//! `0.95` — the grid always contains the incumbent default
//! configuration, so a tuned plan can lose to it only by timing noise.
//! On a 1-participant pool there is no parallelism to reclaim and the
//! gates are skipped (`"skipped"` field set; the file is still written —
//! CI treats an absent file as a broken bench).
//!
//! Correctness is gated before any timing: EVERY candidate in the grid
//! must reproduce the serial reference bitwise, f32 and int8 — the
//! invariance that makes autotuning safe at all.
//!
//! `--smoke` (or `S4_BENCH_SMOKE=1`) shrinks iteration counts for CI;
//! files land in `$S4_BENCH_DIR` (default: cwd).
//!
//! ```bash
//! cargo bench --bench autotune            # full
//! cargo bench --bench autotune -- --smoke # CI trajectory point
//! ```

use std::collections::BTreeSet;
use std::hint::black_box;

use s4::sparse::format::BlockBalanced;
use s4::sparse::matmul::{spmm, Act};
use s4::sparse::pack::{qspmm_tiled_into_plan, spmm_tiled_into_plan};
use s4::sparse::pool::ExecPool;
use s4::sparse::quant::qspmm;
use s4::sparse::tensor::{DType, Dense2};
use s4::sparse::tune::{DispatchPlan, TuneConfig, Tuner};
use s4::util::bench::{Bench, JsonReport};
use s4::util::cli::Args;
use s4::util::json::Json;

/// Geometric mean — the right aggregate for ratios across shape points.
fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

struct Shape {
    m: usize,
    k: usize,
    n: usize,
    sparsity: usize,
    dtype: DType,
}

/// Bitwise gate: every candidate in `cfg`'s grid reproduces the serial
/// reference exactly, for both precisions of this shape's weights.
fn gate_bitwise(
    pool: &ExecPool,
    cfg: &TuneConfig,
    x: &Dense2,
    w: &BlockBalanced,
) -> anyhow::Result<()> {
    let grid = cfg.candidates();
    let tiles: BTreeSet<usize> = grid.iter().map(|c| c.tile_n).collect();
    let serial = spmm(x, w, None, Act::None);
    let qb = w.quantize();
    let qserial = qspmm(x, &qb, None, Act::None);
    let mut out = Dense2::zeros(0, 0);
    let mut qout = Dense2::zeros(0, 0);
    let mut qbuf = Vec::new();
    for &t in &tiles {
        let wt = w.pack_tiled(t);
        let qwt = qb.pack_tiled(t);
        for c in grid.iter().filter(|c| c.tile_n == t) {
            spmm_tiled_into_plan(pool, x, &wt, None, Act::None, *c, &mut out);
            anyhow::ensure!(serial.data == out.data, "f32 diverged at plan {c:?}");
            qspmm_tiled_into_plan(pool, x, &qwt, None, Act::None, *c, &mut qbuf, &mut qout);
            anyhow::ensure!(qserial.data == qout.data, "int8 diverged at plan {c:?}");
        }
    }
    Ok(())
}

/// One measurement sweep: per shape, tune a plan and time tuned vs the
/// fixed-default dispatch. Returns (entries, per-shape ratios).
fn sweep(
    b: &Bench,
    pool: &ExecPool,
    cfg: &TuneConfig,
    shapes: &[Shape],
) -> anyhow::Result<(Vec<Json>, Vec<f64>)> {
    let threads = pool.participants();
    let tuner = Tuner::new(pool, cfg.clone());
    let mut entries = Vec::new();
    let mut ratios = Vec::new();
    for s in shapes {
        let &Shape { m, k, n, sparsity, dtype } = s;
        let tag = format!("m={m:<3} k={k:<4} n={n:<4} {}", dtype.name());
        let x = Dense2::randn(m, k, (m * 31 + n) as u64);
        let w = BlockBalanced::from_dense(&Dense2::randn(k, n, (k + n) as u64), sparsity)?;
        let packed = w.pack();
        let default_plan = DispatchPlan::fixed_default(m, k, threads);
        let mut out = Dense2::zeros(0, 0);
        let (tuned_plan, rd, rt) = match dtype {
            DType::Int8 => {
                let qpacked = w.quantize().pack();
                let plan = tuner.tune_int8(&qpacked, None, Act::None, m);
                let tuned_w = qpacked.repacked(plan.tile_n);
                let mut qbuf = Vec::new();
                let rd = b.run(&format!("qspmm default {tag}"), || {
                    qspmm_tiled_into_plan(
                        pool, black_box(&x), &qpacked, None, Act::None, default_plan,
                        &mut qbuf, &mut out,
                    );
                    black_box(&out);
                });
                let rt = b.run(&format!("qspmm tuned   {tag}"), || {
                    qspmm_tiled_into_plan(
                        pool, black_box(&x), &tuned_w, None, Act::None, plan,
                        &mut qbuf, &mut out,
                    );
                    black_box(&out);
                });
                (plan, rd, rt)
            }
            _ => {
                let plan = tuner.tune_f32(&packed, None, Act::None, m);
                let tuned_w = packed.repacked(plan.tile_n);
                let rd = b.run(&format!("spmm  default {tag}"), || {
                    spmm_tiled_into_plan(
                        pool, black_box(&x), &packed, None, Act::None, default_plan, &mut out,
                    );
                    black_box(&out);
                });
                let rt = b.run(&format!("spmm  tuned   {tag}"), || {
                    spmm_tiled_into_plan(
                        pool, black_box(&x), &tuned_w, None, Act::None, plan, &mut out,
                    );
                    black_box(&out);
                });
                (plan, rd, rt)
            }
        };
        let ratio = rd.summary.p50 / rt.summary.p50;
        ratios.push(ratio);
        entries.push(Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("sparsity", Json::Num(sparsity as f64)),
            ("keep", Json::Num(w.keep() as f64)),
            ("precision", Json::Str(dtype.name().to_string())),
            ("default_tile_n", Json::Num(default_plan.tile_n as f64)),
            ("default_max_stripes", Json::Num(default_plan.max_stripes as f64)),
            ("tuned_tile_n", Json::Num(tuned_plan.tile_n as f64)),
            ("tuned_max_stripes", Json::Num(tuned_plan.max_stripes as f64)),
            ("default_p50_s", Json::Num(rd.summary.p50)),
            ("tuned_p50_s", Json::Num(rt.summary.p50)),
            ("tuned_vs_default_throughput_ratio", Json::Num(ratio)),
        ]));
    }
    Ok((entries, ratios))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let b = if smoke {
        Bench { min_sample_secs: 0.005, samples: 3, warmup_secs: 0.02 }
    } else {
        Bench::default()
    };
    let pool = ExecPool::global();
    let threads = pool.participants();

    // the grid the serving backend would search, with the fixed default
    // configuration guaranteed present (so "tuned" can never be worse
    // than the incumbent by more than noise)
    let mut cfg = if smoke { TuneConfig::quick() } else { TuneConfig::default() };
    cfg.ensure_stripe(threads);

    // small-m × wide-n: the n-blind heuristic (`m·k ≥ 2048`) serializes
    // these despite multiple stripes of compute — the tuner's win;
    // m=64 is the saturated control where default already parallelizes
    let shapes = [
        Shape { m: 2, k: 512, n: 512, sparsity: 8, dtype: DType::F32 },
        Shape { m: 4, k: 256, n: 2048, sparsity: 8, dtype: DType::F32 },
        Shape { m: 2, k: 512, n: 1024, sparsity: 8, dtype: DType::Int8 },
        Shape { m: 64, k: 512, n: 512, sparsity: 8, dtype: DType::F32 },
    ];

    println!("== kernel autotuning vs fixed dispatch ({threads} pool participants) ==");

    // correctness first: every grid candidate must be bitwise-identical
    // to serial on a representative tuned shape before anything is timed
    let gate_x = Dense2::randn(4, 256, 7);
    let gate_w = BlockBalanced::from_dense(&Dense2::randn(256, 320, 8), 8)?;
    gate_bitwise(pool, &cfg, &gate_x, &gate_w)?;
    println!("bitwise gate: all {} grid candidates match serial (f32 + int8)", cfg.candidates().len());

    // smoke runs 3-sample measurements on shared CI runners — retry a
    // losing sweep before failing so one scheduling stall isn't a red
    // build, while a real regression fails every attempt
    let attempts = if smoke { 3 } else { 1 };
    let mut entries = Vec::new();
    let mut ratios = Vec::new();
    for attempt in 1..=attempts {
        (entries, ratios) = sweep(&b, pool, &cfg, &shapes)?;
        let ok = geomean(&ratios) >= 1.05 && ratios.iter().all(|&r| r >= 0.95);
        if ok || threads == 1 {
            break;
        }
        if attempt < attempts {
            println!(
                "tuned geomean {:.2}x below gate — retrying noisy sweep",
                geomean(&ratios)
            );
        }
    }

    let overall = geomean(&ratios);
    let mut report = JsonReport::new("autotune");
    report.set("smoke", Json::Bool(smoke));
    report.set_effective_workers(threads);
    report.set("grid_size", Json::Num(cfg.candidates().len() as f64));
    report.set("tuned_vs_default_throughput_ratio", Json::Num(overall));
    if threads == 1 {
        report.set_skipped("single participant: no parallelism for tuning to reclaim");
    }
    for e in entries {
        report.push(e);
    }
    // write BEFORE asserting: a failing gate must still leave the
    // trajectory point on disk for the CI artifact
    let path = report.write()?;
    println!("\ntuned vs default throughput (geomean): {overall:.2}x");
    println!("wrote {}", path.display());

    if threads == 1 {
        println!("single-participant pool: speedup gates skipped");
        return Ok(());
    }
    for (s, &r) in shapes.iter().zip(&ratios) {
        anyhow::ensure!(
            r >= 0.95,
            "tuned plan regressed shape m={} k={} n={} {}: {r:.3}x < 0.95 — \
             the grid contains the default, this exceeds timing noise",
            s.m, s.k, s.n, s.dtype.name()
        );
    }
    anyhow::ensure!(
        overall >= 1.05,
        "tuned dispatch geomean {overall:.3}x failed the >= 1.05 gate"
    );
    Ok(())
}
