//! Bench: cluster throughput scaling — N sharded nodes behind the
//! router tier vs one node, identical per-node capacity.
//!
//! Emits `BENCH_cluster.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf "Cluster scaling"). Each node is the fixed-service-time stack
//! from the net bench (ThrottledEcho behind one worker ⇒ capacity =
//! `max_batch / service` rps per node, by construction), fronted by a
//! real loopback [`NetServer`]; the [`RouterServer`] rotates replicas
//! over pooled connections. The open-loop generator drives the router at
//! ~85% of the *fleet's* aggregate capacity for N=1 and N=target, and
//! the trajectory point each PR defends is the achieved-throughput
//! ratio:
//!
//! * `n3_vs_n1_throughput_ratio ≥ 1.8` — three nodes must buy at least
//!   1.8× one node's achieved rate through the same router (0.6 × N in
//!   general; the router must spread load, not serialize it);
//! * every run drains clean: `lost == 0` and the router ledger
//!   reconciles (`answered() == admitted`).
//!
//! ```bash
//! cargo bench --bench cluster_scaling                      # full, N=3
//! cargo bench --bench cluster_scaling -- --smoke --nodes 2 # CI point
//! ```

use std::sync::Arc;
use std::time::Duration;

use s4::backend::{EchoBackend, InferenceBackend, TensorSpec, Value};
use s4::cluster::{spawn_local_cluster_cfg, LocalCluster, RouterConfig, RouterServer};
use s4::coordinator::{BatcherConfig, Router, RoutingPolicy, ServerConfig};
use s4::net::{run_open_loop_local, LoadReport, LoadSpec, NetServerConfig, RetryPolicy};
use s4::runtime::Manifest;
use s4::util::bench::JsonReport;
use s4::util::cli::Args;
use s4::util::json::Json;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

/// Echo semantics with a fixed service time per batch — one worker per
/// node gives each node a deterministic `max_batch / service` rps
/// capacity, so fleet capacity is exactly N× and the offered rate can be
/// pinned at a fixed utilization for every N.
struct ThrottledEcho {
    inner: EchoBackend,
    service: Duration,
}

impl InferenceBackend for ThrottledEcho {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.input_specs(artifact)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.output_specs(artifact)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        std::thread::sleep(self.service);
        self.inner.run_batch(artifact, inputs)
    }
}

const MAX_BATCH: usize = 8;

fn fleet(n: usize, service: Duration) -> anyhow::Result<LocalCluster> {
    spawn_local_cluster_cfg(
        n,
        NetServerConfig { max_connections: 512, ..Default::default() },
        move |_i| {
            let m = manifest();
            let backend: Arc<dyn InferenceBackend> =
                Arc::new(ThrottledEcho { inner: EchoBackend::from_manifest(&m), service });
            let cfg = ServerConfig {
                batcher: BatcherConfig { max_batch: MAX_BATCH, max_wait: Duration::from_micros(500) },
                workers: 1,
                max_inflight: 512,
                ..Default::default()
            };
            (cfg, m, Router::new(RoutingPolicy::MaxSparsity), backend)
        },
    )
}

/// One scaling point: fresh N-node fleet, fresh router, open-loop load
/// at `utilization` × fleet capacity, full drain, clean teardown.
fn run_point(
    n: usize,
    service: Duration,
    utilization: f64,
    duration: Duration,
) -> anyhow::Result<(LoadReport, f64)> {
    let cluster = fleet(n, service)?;
    let router = Arc::new(RouterServer::new(
        cluster.spec(),
        RouterConfig {
            replication: n,
            pool_per_node: 256,
            retry: RetryPolicy { attempts: 2, connect_timeout: Duration::from_millis(500), ..Default::default() },
            ..Default::default()
        },
    )?);
    let capacity_rps = n as f64 * MAX_BATCH as f64 / service.as_secs_f64();
    let spec = LoadSpec {
        model: "bert_tiny".into(),
        tokens: (0..32).map(|i| (i * 37 + 11) % 1000).collect(),
        rate_rps: utilization * capacity_rps,
        duration,
        connections: 4,
        mix: [0.2, 0.5, 0.3],
        deadlines: [None, None, None],
        drain_grace: Duration::from_secs(20),
        seed: 0xC1_5CA1E,
    };
    let report = run_open_loop_local(&router, &spec)?;
    let snap = router.metrics_snapshot();
    anyhow::ensure!(report.lost == 0, "N={n}: open-loop harness lost tickets");
    anyhow::ensure!(
        snap.answered() == snap.admitted,
        "N={n}: router ledger must reconcile (answered {} vs admitted {})",
        snap.answered(),
        snap.admitted
    );
    println!(
        "bench cluster/N={n}  offered {:>7.0} rps  achieved {:>7.0} rps  \
         completed {:<6} forwards {:<6} failovers {}",
        report.offered_rps,
        report.achieved_rps,
        report.completed(),
        snap.cluster.forwards,
        snap.cluster.failovers
    );
    cluster.shutdown();
    Ok((report, capacity_rps))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let n = args.get_usize("nodes", 3)?;
    anyhow::ensure!(n >= 2, "scaling needs at least 2 nodes (got {n})");
    // per-node capacity with one worker = max_batch / service
    let (service, duration) = if smoke {
        (Duration::from_millis(4), Duration::from_millis(900))
    } else {
        (Duration::from_millis(4), Duration::from_secs(2))
    };
    let utilization = 0.85;

    println!(
        "== cluster scaling (service {service:?}/batch, {:.0} rps/node, N=1 vs N={n}, \
         {utilization:.0}% load, {duration:?}/point) ==",
        MAX_BATCH as f64 / service.as_secs_f64(),
        utilization = utilization * 100.0
    );

    let mut report = JsonReport::new("cluster");
    report.set("smoke", Json::Bool(smoke));
    report.set("nodes", Json::Num(n as f64));
    report.set("service_us_per_batch", Json::Num(service.as_micros() as f64));
    report.set("utilization", Json::Num(utilization));
    report.set("duration_s_per_point", Json::Num(duration.as_secs_f64()));

    let (single, cap1) = run_point(1, service, utilization, duration)?;
    let (fleet_r, capn) = run_point(n, service, utilization, duration)?;

    for (label, cap, r) in [("n1", cap1, &single), ("fleet", capn, &fleet_r)] {
        report.push(Json::obj(vec![
            ("point", Json::Str(label.into())),
            ("capacity_rps", Json::Num(cap)),
            ("offered_rps", Json::Num(r.offered_rps)),
            ("achieved_rps", Json::Num(r.achieved_rps)),
            ("completed", Json::Num(r.completed() as f64)),
        ]));
    }

    let ratio = fleet_r.achieved_rps / single.achieved_rps.max(1.0);
    report.set("throughput_ratio_vs_single", Json::Num(ratio));
    if n == 3 {
        // the canonical trajectory key EXPERIMENTS.md tracks
        report.set("n3_vs_n1_throughput_ratio", Json::Num(ratio));
    }

    println!(
        "bench cluster/summary  N=1 achieved {:.0} rps, N={n} achieved {:.0} rps \
         (ratio {ratio:.2}x, floor {:.2}x)",
        single.achieved_rps,
        fleet_r.achieved_rps,
        0.6 * n as f64
    );

    // the headline claim: N nodes through the same router must buy at
    // least 0.6×N the single-node achieved rate (N=3 ⇒ 1.8×)
    anyhow::ensure!(
        ratio >= 0.6 * n as f64,
        "cluster must scale: N={n} achieved only {ratio:.2}x of single-node \
         ({:.0} vs {:.0} rps; floor {:.2}x)",
        fleet_r.achieved_rps,
        single.achieved_rps,
        0.6 * n as f64
    );

    let path = report.write()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
