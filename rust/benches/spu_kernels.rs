//! Bench: the sparse-matmul substrate — reference `spmm` across sparsity
//! levels (compute scales ~1/s: the kernel-level Fig. 2 premise on the
//! host reference implementation) plus the balanced-vs-CSR ablation the
//! DESIGN.md calls out (why the *balanced* constraint is what the SPU
//! needs).

use s4::sparse::format::{BlockBalanced, Csr};
use s4::sparse::matmul::{csr_mm, dense_mm, spmm, Act};
use s4::sparse::tensor::Dense2;
use s4::util::bench::Bench;

fn main() {
    let b = Bench::default();
    let (m, k, n) = (64usize, 1024usize, 256usize);
    let x = Dense2::randn(m, k, 1);
    let wd = Dense2::randn(k, n, 2);

    let dense = b.run("dense_mm 64x1024x256", || {
        std::hint::black_box(dense_mm(&x, &wd, None, Act::None));
    });

    println!();
    let mut last = f64::INFINITY;
    for s in [1usize, 2, 4, 8, 16, 32] {
        let w = BlockBalanced::from_dense(&wd, s).unwrap();
        let r = b.run(&format!("spmm s={s:<2} 64x1024x256"), || {
            std::hint::black_box(spmm(&x, &w, None, Act::None));
        });
        assert!(
            r.summary.p50 < last * 1.15,
            "spmm should not get slower with sparsity (s={s})"
        );
        last = r.summary.p50;
    }
    println!(
        "\n(spmm s=32 vs dense reference: {:.1}x)",
        dense.summary.p50 / last
    );

    // ablation: unstructured CSR at the same nnz — the irregular layout a
    // balanced systolic array avoids
    println!("\nbalanced vs unstructured (same nnz, s=8):");
    let w8 = BlockBalanced::from_dense(&wd, 8).unwrap();
    let csr = Csr::from_dense(&w8.to_dense());
    let rb = b.run("  block-balanced spmm", || {
        std::hint::black_box(spmm(&x, &w8, None, Act::None));
    });
    let rc = b.run("  csr spmm (unstructured)", || {
        std::hint::black_box(csr_mm(&x, &csr));
    });
    println!(
        "  storage: balanced {} B vs CSR {} B ({:.2}x)",
        w8.bytes(s4::sparse::tensor::DType::Bf16),
        csr.bytes(s4::sparse::tensor::DType::Bf16),
        csr.bytes(s4::sparse::tensor::DType::Bf16) as f64
            / w8.bytes(s4::sparse::tensor::DType::Bf16) as f64
    );
    let _ = (rb, rc);
}
