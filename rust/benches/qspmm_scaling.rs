//! Bench: the INT8 quantized packed kernel vs the f32 tiled engine —
//! the paper's sparsity × quantization composition as a measured curve.
//!
//! Emits `BENCH_qspmm.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf): for every (sparsity ∈ {1,2,4,8,16,32}) × (thread count)
//! point, the int8 kernel's GFLOP/s (sparse-effective, i.e. dense FLOPs
//! ÷ sparsity over wall time) and its speedup over the f32 tiled kernel
//! at the same point — the int8-vs-f32 tradeoff each PR defends.
//!
//! Before any timing, the run gates on correctness: `qspmm_tiled` must
//! match the serial int8 reference bitwise, and stay within the analytic
//! quantization-error bound of the f32 kernel.
//!
//! `--smoke` (or `S4_BENCH_SMOKE=1`) shrinks shapes and iteration counts
//! for CI; files land in `$S4_BENCH_DIR` (default: cwd).
//!
//! ```bash
//! cargo bench --bench qspmm_scaling            # full
//! cargo bench --bench qspmm_scaling -- --smoke # CI trajectory point
//! ```

use std::hint::black_box;

use s4::sparse::format::BlockBalanced;
use s4::sparse::matmul::{spmm, Act};
use s4::sparse::pack::{qspmm_tiled, spmm_tiled};
use s4::sparse::quant::{qspmm, quant_drift_bound};
use s4::sparse::tensor::Dense2;
use s4::util::bench::{Bench, JsonReport};
use s4::util::cli::Args;
use s4::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let b = if smoke {
        Bench { min_sample_secs: 0.005, samples: 3, warmup_secs: 0.02 }
    } else {
        Bench::default()
    };
    let (m, k, n) = if smoke { (32, 256, 128) } else { (128, 1024, 256) };
    // clamp the sweep to what the ExecPool can actually dispatch (see
    // spmm_scaling.rs — points past the cap would re-measure the cap)
    let pool = s4::sparse::ExecPool::global();
    let cap = pool.participants();
    let mut threads = args.get_usize_list("threads", &[1, 2, 4, 8])?;
    pool.clamp_thread_sweep(&mut threads);
    let x = Dense2::randn(m, k, 1);
    let wd = Dense2::randn(k, n, 2);
    let dense_flops = 2.0 * (m * k * n) as f64;

    println!("== qspmm scaling: int8 vs f32 ({m}x{k}x{n}, threads {threads:?} cap {cap}) ==");
    let mut report = JsonReport::new("qspmm");
    report.set("smoke", Json::Bool(smoke));
    report.set_effective_workers(threads.iter().copied().max().unwrap_or(1));
    report.set(
        "shape",
        Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
        ]),
    );

    for &s in &s4::sparse::SUPPORTED_SPARSITIES {
        let w = BlockBalanced::from_dense(&wd, s)?;
        let packed = w.pack();
        let qb = w.quantize();
        let qpacked = qb.pack();

        // correctness gates before any timing is recorded:
        // (1) tiled int8 == serial int8, bitwise
        let serial_q = qspmm(&x, &qb, None, Act::None);
        let tiled_q = qspmm_tiled(&x, &qpacked, None, Act::None, 4);
        anyhow::ensure!(
            serial_q.data == tiled_q.data,
            "int8 tiled kernel diverged from serial reference at s={s}"
        );
        // (2) int8 within the worst-case quantization bound of f32
        // (shared definition with the differential property test)
        let f32_ref = spmm(&x, &w, None, Act::None);
        let bound = quant_drift_bound(&x, &w, &qb);
        let drift = tiled_q.max_abs_diff(&f32_ref);
        anyhow::ensure!(drift <= bound, "int8 drift {drift} > bound {bound} at s={s}");

        let flops = dense_flops / s as f64;
        for &t in &threads {
            let rf = b.run(&format!("spmm_tiled  s={s:<2} t={t}"), || {
                black_box(spmm_tiled(&x, &packed, None, Act::None, t));
            });
            let rq = b.run(&format!("qspmm_tiled s={s:<2} t={t}"), || {
                black_box(qspmm_tiled(&x, &qpacked, None, Act::None, t));
            });
            report.push(Json::obj(vec![
                ("sparsity", Json::Num(s as f64)),
                ("threads", Json::Num(t as f64)),
                ("f32_p50_s", Json::Num(rf.summary.p50)),
                ("int8_p50_s", Json::Num(rq.summary.p50)),
                ("int8_gflops", Json::Num(flops / rq.summary.p50 / 1e9)),
                ("speedup_vs_f32", Json::Num(rf.summary.p50 / rq.summary.p50)),
            ]));
        }
    }
    let path = report.write()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
