//! Bench: the parallel tiled SpMM engine + real-sparse serving, with a
//! machine-readable perf trajectory.
//!
//! Emits (schema `s4-bench-v1`, see EXPERIMENTS.md §Perf):
//! * `BENCH_spmm.json` — GFLOP/s, speedup-vs-serial and speedup-vs-dense
//!   for every (sparsity ∈ {1,2,4,8,16,32}) × (thread count) point, so
//!   the paper's "linear speedup from balanced sparsity" claim is a
//!   measured curve, not an asymptote (The Sparsity Roofline's demand);
//! * `BENCH_serving.json` — closed-loop p50/p99/throughput through the
//!   coordinator for the instant Echo backend (pure overhead) and the
//!   CpuSparseBackend (real sparse compute on the request path).
//!
//! `--smoke` (or `S4_BENCH_SMOKE=1`) shrinks shapes and iteration counts
//! for CI; files land in `$S4_BENCH_DIR` (default: cwd).
//!
//! ```bash
//! cargo bench --bench spmm_scaling            # full
//! cargo bench --bench spmm_scaling -- --smoke # CI trajectory point
//! ```

use std::hint::black_box;
use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{CpuSparseBackend, EchoBackend, InferenceBackend, Value};
use s4::coordinator::{BatcherConfig, Router, RoutingPolicy, Server, ServerConfig};
use s4::runtime::Manifest;
use s4::sparse::format::BlockBalanced;
use s4::sparse::matmul::{dense_mm, spmm, Act};
use s4::sparse::pack::spmm_tiled;
use s4::sparse::tensor::Dense2;
use s4::util::bench::{Bench, JsonReport};
use s4::util::cli::Args;
use s4::util::json::Json;
use s4::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let spmm_path = bench_spmm(&args, smoke)?;
    let serving_path = bench_serving(&args, smoke)?;
    println!("\nwrote {}", spmm_path.display());
    println!("wrote {}", serving_path.display());
    Ok(())
}

// ----------------------------- kernel scaling ------------------------------

fn bench_spmm(args: &Args, smoke: bool) -> anyhow::Result<std::path::PathBuf> {
    let b = if smoke {
        Bench { min_sample_secs: 0.005, samples: 3, warmup_secs: 0.02 }
    } else {
        Bench::default()
    };
    let (m, k, n) = if smoke { (32, 256, 128) } else { (128, 1024, 256) };
    // the tiled kernels dispatch through the process-wide ExecPool, which
    // caps concurrent stripes at its participant count — sweep points
    // beyond that would silently re-measure the cap, so drop them instead
    // of recording thread counts the pool never ran
    let pool = s4::sparse::ExecPool::global();
    let cap = pool.participants();
    let mut threads = args.get_usize_list("threads", &[1, 2, 4, 8])?;
    pool.clamp_thread_sweep(&mut threads);
    let x = Dense2::randn(m, k, 1);
    let wd = Dense2::randn(k, n, 2);
    let dense_flops = 2.0 * (m * k * n) as f64;

    println!("== spmm scaling ({m}x{k}x{n}, threads {threads:?} [pool cap {cap}]) ==");
    let rd = b.run(&format!("dense_mm {m}x{k}x{n}"), || {
        black_box(dense_mm(&x, &wd, None, Act::None));
    });
    let dense_p50 = rd.summary.p50;

    let mut report = JsonReport::new("spmm");
    report.set("smoke", Json::Bool(smoke));
    // widest point the pool actually dispatched (sweep is pre-clamped)
    report.set_effective_workers(threads.iter().copied().max().unwrap_or(1));
    report.set(
        "shape",
        Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
        ]),
    );
    report.set("dense_p50_s", Json::Num(dense_p50));
    report.set("dense_gflops", Json::Num(dense_flops / dense_p50 / 1e9));

    for &s in &s4::sparse::SUPPORTED_SPARSITIES {
        let w = BlockBalanced::from_dense(&wd, s)?;
        let packed = w.pack();
        // correctness gate before any timing is recorded
        let serial = spmm(&x, &w, None, Act::None);
        let diff = serial.max_abs_diff(&spmm_tiled(&x, &packed, None, Act::None, 4));
        anyhow::ensure!(diff <= 1e-4, "tiled kernel diverged at s={s}: {diff}");

        let flops = dense_flops / s as f64;
        let rs = b.run(&format!("spmm_serial s={s:<2}"), || {
            black_box(spmm(&x, &w, None, Act::None));
        });
        for &t in &threads {
            let rt = b.run(&format!("spmm_tiled  s={s:<2} t={t}"), || {
                black_box(spmm_tiled(&x, &packed, None, Act::None, t));
            });
            report.push(Json::obj(vec![
                ("sparsity", Json::Num(s as f64)),
                ("threads", Json::Num(t as f64)),
                ("serial_p50_s", Json::Num(rs.summary.p50)),
                ("tiled_p50_s", Json::Num(rt.summary.p50)),
                ("gflops", Json::Num(flops / rt.summary.p50 / 1e9)),
                (
                    "speedup_vs_serial",
                    Json::Num(rs.summary.p50 / rt.summary.p50),
                ),
                ("speedup_vs_dense", Json::Num(dense_p50 / rt.summary.p50)),
            ]));
        }
    }
    report.write()
}

// ------------------------------- serving -----------------------------------

fn serving_manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

/// Closed-loop run: submit `n` requests, wait for all, report latency
/// percentiles + throughput. Returns one trajectory entry.
fn closed_loop(backend: Arc<dyn InferenceBackend>, n: usize, label: &str) -> Json {
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            workers: 4,
            max_inflight: 4096,
            ..Default::default()
        },
        serving_manifest(),
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let t0 = Instant::now();
    // closed loop over exactly `n` requests: admission rejections
    // (inflight > max_inflight under this burst) are retried, not
    // dropped, so trajectory entries are comparable across runs. The
    // retry deadline turns a wedged server into a bench failure rather
    // than a CI hang.
    let submit_deadline = Instant::now() + Duration::from_secs(120);
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        loop {
            match h.submit("bert_tiny", vec![Value::tokens(vec![i as i32 % 997; 32])]) {
                Ok(t) => {
                    tickets.push(t);
                    break;
                }
                Err(_) => {
                    assert!(
                        Instant::now() < submit_deadline,
                        "submit retry deadline exceeded after {} of {n} requests \
                         (server wedged?)",
                        tickets.len()
                    );
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        }
    }
    let mut lat_us = Vec::with_capacity(tickets.len());
    for t in tickets {
        let r = t.wait_timeout(Duration::from_secs(60)).expect("response");
        assert!(r.is_ok(), "{:?}", r.status);
        lat_us.push(r.latency_us as f64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::of(&lat_us);
    let rps = lat_us.len() as f64 / wall;
    println!(
        "bench serving/{label:<24} {rps:>9.0} req/s  p50 {:>8.0}µs  p99 {:>8.0}µs  fill {:.2}",
        s.p50,
        s.p99,
        h.metrics.mean_batch_fill(),
    );
    let entry = Json::obj(vec![
        ("backend", Json::Str(label.into())),
        ("requests", Json::Num(lat_us.len() as f64)),
        ("throughput_rps", Json::Num(rps)),
        ("p50_us", Json::Num(s.p50)),
        ("p99_us", Json::Num(s.p99)),
        ("mean_batch_fill", Json::Num(h.metrics.mean_batch_fill())),
    ]);
    srv.shutdown();
    entry
}

fn bench_serving(_args: &Args, smoke: bool) -> anyhow::Result<std::path::PathBuf> {
    let m = serving_manifest();
    println!("\n== serving (coordinator overhead + real sparse compute) ==");
    let mut report = JsonReport::new("serving");
    report.set("smoke", Json::Bool(smoke));
    // serving compute dispatches on the process-wide pool, bounded by
    // CpuSparseBackend::from_manifest's default thread cap
    report.set_effective_workers(
        s4::sparse::ExecPool::global().participants().min(CpuSparseBackend::DEFAULT_THREAD_CAP),
    );
    let (n_echo, n_cpu) = if smoke { (2_000, 500) } else { (20_000, 5_000) };
    // instant backend: isolates coordinator overhead (§Perf target:
    // p50 < 200 µs/request)
    report.push(closed_loop(
        Arc::new(EchoBackend::from_manifest(&m)),
        n_echo,
        "echo_overhead",
    ));
    // real sparse compute on the request path
    report.push(closed_loop(
        Arc::new(CpuSparseBackend::from_manifest(&m)),
        n_cpu,
        "cpu_sparse",
    ));
    report.write()
}
