//! Bench: regenerate paper **Figure 3** — accuracy & throughput of
//! {ResNet-50/152, BERT-base/large} dense-on-T4 vs sparse-on-S4 at
//! s ∈ {1,2,4,8,16} — and assert the dominance claim holds on every run.

use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::report::{dominates, fig3_table, Fig3Point};
use s4::sim::{simulate, Target};
use s4::util::bench::Bench;

fn accuracy(model: &str, sparsity: usize) -> f64 {
    // published dense accuracy with the §4 pruning decay (see
    // examples/accuracy_frontier.rs for the measured-proxy variant)
    let dense: f64 = match model {
        "resnet50" => 0.761,
        "resnet152" => 0.783,
        "bert_base" => 0.781,
        "bert_large" => 0.805,
        _ => 0.75,
    };
    let relief = if matches!(model, "resnet152" | "bert_large") { 0.5 } else { 1.0 };
    let decay = match sparsity {
        1 => 0.0,
        2 => 0.002,
        4 => 0.004,
        8 => 0.008,
        16 => 0.014,
        _ => 0.03,
    };
    dense - decay * relief
}

fn main() {
    let cfg = AntoumConfig::s4();
    let batch = 16;
    let mut points = Vec::new();
    for name in ["resnet50", "resnet152", "bert_base", "bert_large"] {
        let g = models::by_name(name, batch).unwrap();
        let t4 = simulate(&g, Target::t4());
        points.push(Fig3Point {
            model: name.into(),
            platform: "T4".into(),
            sparsity: 1,
            accuracy: accuracy(name, 1),
            throughput: t4.throughput,
        });
        for &s in &[1usize, 2, 4, 8, 16] {
            let r = simulate(&g, Target::antoum(&cfg, s));
            points.push(Fig3Point {
                model: name.into(),
                platform: "S4".into(),
                sparsity: s,
                accuracy: accuracy(name, s),
                throughput: r.throughput,
            });
        }
    }
    print!("{}", fig3_table(&points));

    // dominance assertions (the figure's takeaway)
    for (big, small) in [("resnet152", "resnet50"), ("bert_large", "bert_base")] {
        let dense_small = points
            .iter()
            .find(|p| p.model == small && p.platform == "T4")
            .unwrap();
        let dominated = points
            .iter()
            .filter(|p| p.model == big && p.platform == "S4")
            .any(|p| dominates(p, dense_small));
        assert!(dominated, "{big} sparse must dominate {small} dense");
        println!("✓ {big} sparse-on-S4 dominates {small} dense-on-T4");
    }

    // timing: frontier generation
    let b = Bench::default();
    b.run("fig3_frontier(24 sims)", || {
        for name in ["resnet50", "resnet152", "bert_base", "bert_large"] {
            let g = models::by_name(name, batch).unwrap();
            std::hint::black_box(simulate(&g, Target::t4()));
            for &s in &[1usize, 2, 4, 8, 16] {
                std::hint::black_box(simulate(&g, Target::antoum(&cfg, s)));
            }
        }
    });
}
