//! Bench: serving latency through the TCP front end, open-loop, with a
//! machine-readable perf trajectory.
//!
//! Emits `BENCH_net.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf "Network serving"). The same fixed-service-time stack as the
//! QoS bench (ThrottledEcho behind one worker, capacity = batch/service)
//! is driven by the open-loop generator at three offered rates — ~25%,
//! ~50%, and ~150% of saturation — through a real socket
//! ([`run_open_loop`]); at the matched mid rate, the *identical*
//! schedule (same seed, same classes, same deadlines) is replayed
//! straight into the coordinator ([`run_open_loop_local`]), so the
//! socket's cost is a like-for-like subtraction, not a guess.
//!
//! Trajectory points each PR defends:
//! * `socket_overhead_ratio` — socket-path Interactive p99 ≤ 3× the
//!   in-process figure at matched load (the wire must not swamp QoS);
//! * past saturation the harness must *see* the overload: shed work
//!   (admission rejections and expired Bulk) > 0, achieved < offered.
//!
//! ```bash
//! cargo bench --bench net_latency            # full
//! cargo bench --bench net_latency -- --smoke # CI trajectory point
//! ```

use std::sync::Arc;
use std::time::Duration;

use s4::backend::{EchoBackend, InferenceBackend, TensorSpec, Value};
use s4::coordinator::{
    BatcherConfig, Priority, Router, RoutingPolicy, Server, ServerConfig, ServerHandle,
};
use s4::net::{run_open_loop, run_open_loop_local, LoadReport, LoadSpec, NetServer, NetServerConfig};
use s4::runtime::Manifest;
use s4::util::bench::JsonReport;
use s4::util::cli::Args;
use s4::util::json::Json;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

/// Echo semantics with a fixed service time per batch — deterministic
/// capacity (`max_batch / service` rps with one worker), so offered
/// rates can be placed below/at/above saturation by construction.
struct ThrottledEcho {
    inner: EchoBackend,
    service: Duration,
}

impl InferenceBackend for ThrottledEcho {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.input_specs(artifact)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.output_specs(artifact)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        std::thread::sleep(self.service);
        self.inner.run_batch(artifact, inputs)
    }
}

/// A fresh serving stack per experiment, so backlog from an overload run
/// can never leak into the next rate point.
fn serve_stack(service: Duration) -> (Server, Arc<ServerHandle>) {
    let m = manifest();
    let backend = Arc::new(ThrottledEcho { inner: EchoBackend::from_manifest(&m), service });
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            workers: 1,
            max_inflight: 256,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let handle = Arc::new(srv.handle());
    (srv, handle)
}

fn spec_at(rate: f64, duration: Duration, bulk_deadline: Duration) -> LoadSpec {
    LoadSpec {
        model: "bert_tiny".into(),
        tokens: (0..32).map(|i| (i * 37 + 11) % 1000).collect(),
        rate_rps: rate,
        duration,
        connections: 2,
        mix: [0.2, 0.5, 0.3],
        // Bulk carries a deadline it cannot meet from the back of an
        // overloaded queue — past saturation, expiry must show up
        deadlines: [None, None, Some(bulk_deadline)],
        drain_grace: Duration::from_secs(10),
        seed: 0x4E45_5401,
    }
}

/// One socket-path experiment: fresh stack, fresh NetServer on port 0,
/// open-loop load, full drain, clean shutdown.
fn run_socket(spec: &LoadSpec, service: Duration) -> anyhow::Result<LoadReport> {
    let (srv, handle) = serve_stack(service);
    let net = Arc::new(NetServer::bind("127.0.0.1:0", handle, NetServerConfig::default())?);
    let addr = net.local_addr();
    {
        let net = net.clone();
        srv.on_shutdown(move || net.shutdown());
    }
    let report = run_open_loop(addr, spec)?;
    srv.shutdown();
    Ok(report)
}

/// The matched in-process experiment: identical schedule, no socket.
fn run_inproc(spec: &LoadSpec, service: Duration) -> anyhow::Result<LoadReport> {
    let (srv, handle) = serve_stack(service);
    let report = run_open_loop_local(&handle, spec)?;
    srv.shutdown();
    Ok(report)
}

fn class_rows(scenario: &str, rate: f64, r: &LoadReport) -> Vec<Json> {
    let mut rows = Vec::new();
    for p in Priority::ALL {
        let c = r.class(p);
        println!(
            "bench net/{scenario:<8} rate {rate:>6.0}  {:<12} offered={:<5} ok={:<5} \
             exp={:<4} rej={:<4} p50 {:>8.0}µs  p99 {:>8.0}µs  p999 {:>8.0}µs",
            p.as_str(),
            c.offered,
            c.completed,
            c.expired,
            c.rejected,
            c.p50_us,
            c.p99_us,
            c.p999_us
        );
        rows.push(Json::obj(vec![
            ("scenario", Json::Str(scenario.into())),
            ("offered_rps", Json::Num(rate)),
            ("class", Json::Str(p.as_str().into())),
            ("offered", Json::Num(c.offered as f64)),
            ("completed", Json::Num(c.completed as f64)),
            ("expired", Json::Num(c.expired as f64)),
            ("rejected", Json::Num(c.rejected as f64)),
            ("errors", Json::Num(c.errors as f64)),
            ("p50_us", Json::Num(c.p50_us)),
            ("p99_us", Json::Num(c.p99_us)),
            ("p999_us", Json::Num(c.p999_us)),
            ("achieved_rps", Json::Num(r.achieved_rps)),
        ]));
    }
    rows
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    // capacity with one worker = max_batch / service
    let (service, duration, bulk_deadline) = if smoke {
        (Duration::from_millis(2), Duration::from_millis(800), Duration::from_millis(25))
    } else {
        (Duration::from_millis(4), Duration::from_secs(2), Duration::from_millis(50))
    };
    let capacity_rps = 8.0 / service.as_secs_f64();
    // ~25%, ~50% (the matched-comparison point), ~150% of saturation
    let rates = [0.25 * capacity_rps, 0.5 * capacity_rps, 1.5 * capacity_rps];
    let mid = rates[1];

    println!(
        "== net latency (service {service:?}/batch, capacity ~{capacity_rps:.0} rps, \
         {duration:?}/rate, bulk deadline {bulk_deadline:?}) =="
    );

    let mut report = JsonReport::new("net");
    report.set("smoke", Json::Bool(smoke));
    // synthetic-delay backend behind one coordinator worker
    report.set_effective_workers(1);
    report.set("service_us_per_batch", Json::Num(service.as_micros() as f64));
    report.set("capacity_rps", Json::Num(capacity_rps));
    report.set("bulk_deadline_us", Json::Num(bulk_deadline.as_micros() as f64));
    report.set("duration_s_per_rate", Json::Num(duration.as_secs_f64()));

    let mut overload: Option<LoadReport> = None;
    let mut socket_mid: Option<LoadReport> = None;
    for &rate in &rates {
        let spec = spec_at(rate, duration, bulk_deadline);
        let r = run_socket(&spec, service)?;
        for row in class_rows("socket", rate, &r) {
            report.push(row);
        }
        if (rate - mid).abs() < 1e-9 {
            socket_mid = Some(r.clone());
        }
        if rate > capacity_rps {
            overload = Some(r.clone());
        }
    }

    // matched in-process run: same seed ⇒ identical arrival schedule
    let spec = spec_at(mid, duration, bulk_deadline);
    let inproc = run_inproc(&spec, service)?;
    for row in class_rows("inproc", mid, &inproc) {
        report.push(row);
    }

    let socket_mid = socket_mid.expect("mid rate ran");
    let overload = overload.expect("overload rate ran");

    // headline: what does the socket cost the latency-critical class at
    // healthy load?
    let sock_p99 = socket_mid.class(Priority::Interactive).p99_us;
    let local_p99 = inproc.class(Priority::Interactive).p99_us;
    anyhow::ensure!(
        socket_mid.class(Priority::Interactive).completed > 0,
        "socket run must complete interactive traffic"
    );
    anyhow::ensure!(
        inproc.class(Priority::Interactive).completed > 0,
        "in-process run must complete interactive traffic"
    );
    let ratio = sock_p99 / local_p99.max(1.0);
    report.set("socket_interactive_p99_us", Json::Num(sock_p99));
    report.set("inproc_interactive_p99_us", Json::Num(local_p99));
    report.set("socket_overhead_ratio", Json::Num(ratio));
    report.set("overload_shed", Json::Num(overload.shed() as f64));
    report.set("overload_achieved_rps", Json::Num(overload.achieved_rps));
    report.set("overload_offered_rps", Json::Num(overload.offered_rps));

    println!(
        "bench net/summary   interactive p99: socket {sock_p99:.0}µs vs in-process \
         {local_p99:.0}µs  (ratio {ratio:.2}x)  overload: achieved {:.0}/{:.0} rps, \
         shed {}",
        overload.achieved_rps,
        overload.offered_rps,
        overload.shed()
    );

    anyhow::ensure!(
        ratio <= 3.0,
        "socket path must stay within 3x of in-process interactive p99 at matched load \
         (got {ratio:.2}x: socket {sock_p99:.0}µs vs {local_p99:.0}µs)"
    );
    anyhow::ensure!(
        overload.shed() > 0,
        "past saturation the harness must observe shed work (rejected/expired)"
    );
    anyhow::ensure!(
        overload.achieved_rps < overload.offered_rps,
        "past saturation achieved rate must fall below offered \
         (achieved {:.0} vs offered {:.0})",
        overload.achieved_rps,
        overload.offered_rps
    );

    let path = report.write()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
