//! Bench: dispatch overhead of the persistent stripe-execution pool —
//! per-layer SpMM latency, pooled vs spawn-per-call vs serial.
//!
//! This is the measurement behind the ExecPool's existence: the serving
//! hot path runs one `spmm_tiled`/`qspmm_tiled` per layer per batch, and
//! for the small-`m` shapes the Interactive QoS class produces (1..8
//! rows), `std::thread::scope`'s per-call spawn+join used to cost more
//! than the matmul. The pool parks its workers between layers and wakes
//! them with two lock round-trips.
//!
//! Emits `BENCH_pool.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf "Dispatch overhead"): per shape point the p50 latency of the
//! pooled, spawn-per-call, and serial paths, plus the derived speedups.
//! The run **fails** (non-zero exit, so CI fails loudly) unless
//! `pooled_small_m_speedup_vs_spawn > 1` — the pool must actually beat
//! the spawn discipline where it matters. In `--smoke` mode (3-sample
//! measurements on shared CI runners) a failing sweep is retried a
//! couple of times first, so a single noisy-neighbor stall doesn't fail
//! an unrelated PR; a *consistent* loss still fails the build.
//!
//! Correctness is gated before any timing: all three paths must agree
//! bitwise.
//!
//! `--smoke` (or `S4_BENCH_SMOKE=1`) shrinks iteration counts for CI;
//! files land in `$S4_BENCH_DIR` (default: cwd).
//!
//! ```bash
//! cargo bench --bench pool_latency            # full
//! cargo bench --bench pool_latency -- --smoke # CI trajectory point
//! ```

use std::hint::black_box;

use s4::sparse::format::BlockBalanced;
use s4::sparse::matmul::{spmm, Act};
use s4::sparse::pack::{spmm_tiled_into, spmm_tiled_scoped, PackedBlockBalanced};
use s4::sparse::pool::ExecPool;
use s4::sparse::tensor::Dense2;
use s4::util::bench::{Bench, JsonReport};
use s4::util::cli::Args;
use s4::util::json::Json;

/// Geometric mean — the right aggregate for ratios across shape points.
fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// One full measurement sweep over the shape points. Returns the
/// trajectory entries and the small-m pooled-vs-spawn ratios the gate
/// aggregates (empty on a 1-participant pool — nothing measures
/// dispatch there).
fn sweep(
    b: &Bench,
    pool: &ExecPool,
    w: &BlockBalanced,
    packed: &PackedBlockBalanced,
    small_m: &[usize],
    large_m: &[usize],
    k: usize,
) -> anyhow::Result<(Vec<Json>, Vec<f64>)> {
    let threads = pool.participants();
    let mut entries = Vec::new();
    let mut ratios = Vec::new();
    for &m in small_m.iter().chain(large_m) {
        let x = Dense2::randn(m, k, m as u64);
        // correctness gate: the three dispatch paths agree bitwise
        let serial_ref = spmm(&x, w, None, Act::None);
        let mut pooled_out = Dense2::zeros(0, 0);
        spmm_tiled_into(pool, &x, packed, None, Act::None, threads, &mut pooled_out);
        anyhow::ensure!(serial_ref.data == pooled_out.data, "pooled diverged at m={m}");
        let scoped_ref = spmm_tiled_scoped(&x, packed, None, Act::None, threads);
        anyhow::ensure!(serial_ref.data == scoped_ref.data, "scoped diverged at m={m}");

        // serial: the same kernel, one stripe, no dispatch at all
        let rs = b.run(&format!("spmm serial      m={m:<3}"), || {
            spmm_tiled_into(pool, black_box(&x), packed, None, Act::None, 1, &mut pooled_out);
            black_box(&pooled_out);
        });
        // pooled: parked persistent workers, woken per call
        let rp = b.run(&format!("spmm pooled      m={m:<3}"), || {
            spmm_tiled_into(pool, black_box(&x), packed, None, Act::None, threads, &mut pooled_out);
            black_box(&pooled_out);
        });
        // spawn-per-call: the pre-pool std::thread::scope discipline
        let rv = b.run(&format!("spmm spawn/call  m={m:<3}"), || {
            black_box(spmm_tiled_scoped(black_box(&x), packed, None, Act::None, threads));
        });
        let speedup_vs_spawn = rv.summary.p50 / rp.summary.p50;
        // only multi-stripe points measure dispatch: m == 1 collapses
        // every path to the same serial fast path, and a 1-participant
        // pool (single-core host) has no dispatch to amortize
        if m > 1 && threads > 1 && small_m.contains(&m) {
            ratios.push(speedup_vs_spawn);
        }
        entries.push(Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("threads", Json::Num(threads as f64)),
            ("serial_p50_s", Json::Num(rs.summary.p50)),
            ("pooled_p50_s", Json::Num(rp.summary.p50)),
            ("spawn_p50_s", Json::Num(rv.summary.p50)),
            ("pooled_speedup_vs_spawn", Json::Num(speedup_vs_spawn)),
            ("pooled_speedup_vs_serial", Json::Num(rs.summary.p50 / rp.summary.p50)),
        ]));
    }
    Ok((entries, ratios))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let b = if smoke {
        Bench { min_sample_secs: 0.005, samples: 3, warmup_secs: 0.02 }
    } else {
        Bench::default()
    };
    let (k, n, sparsity) = (512usize, 512usize, 8usize);
    // small-m: the Interactive serving regime the pool exists for;
    // large-m: the saturated regime where dispatch cost should wash out
    let small_m: &[usize] = &[1, 2, 4, 8];
    let large_m: &[usize] = if smoke { &[64] } else { &[64, 128] };
    let pool = ExecPool::global();

    println!(
        "== pool dispatch latency ({k}x{n} s={sparsity}, {} pool workers + caller) ==",
        pool.workers()
    );
    let wd = Dense2::randn(k, n, 2);
    let w = BlockBalanced::from_dense(&wd, sparsity)?;
    let packed = w.pack();

    // smoke mode runs 3-sample measurements on shared CI runners — retry
    // a losing sweep before failing, so one scheduling stall isn't a red
    // build, while a real regression fails every attempt
    let attempts = if smoke { 3 } else { 1 };
    let mut entries = Vec::new();
    let mut ratios = Vec::new();
    for attempt in 1..=attempts {
        (entries, ratios) = sweep(&b, pool, &w, &packed, small_m, large_m, k)?;
        if ratios.is_empty() || geomean(&ratios) > 1.0 {
            break;
        }
        if attempt < attempts {
            println!("small-m speedup {:.2}x <= 1 — retrying noisy sweep", geomean(&ratios));
        }
    }

    let mut report = JsonReport::new("pool");
    report.set("smoke", Json::Bool(smoke));
    report.set_effective_workers(pool.participants());
    if ratios.is_empty() {
        // still emit BENCH_pool.json: CI treats an absent file as a
        // broken bench, and a skipped gate should say why
        report.set_skipped("single-core host: no multi-stripe points measure dispatch");
    }
    report.set(
        "shape",
        Json::obj(vec![
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("sparsity", Json::Num(sparsity as f64)),
        ]),
    );
    for e in entries {
        report.push(e);
    }
    let small_m_speedup = geomean(&ratios);
    report.set("pooled_small_m_speedup_vs_spawn", Json::Num(small_m_speedup));
    let path = report.write()?;
    println!("\nsmall-m pooled speedup vs spawn-per-call: {small_m_speedup:.2}x");
    println!("wrote {}", path.display());
    // the in-bench assertion: amortized dispatch must beat
    // spawn-per-call on the small-batch serving shapes (skipped only on
    // a single-core host, where no point measures dispatch at all)
    if ratios.is_empty() {
        println!("single-core host: no multi-stripe points, speedup gate skipped");
    } else {
        anyhow::ensure!(
            small_m_speedup > 1.0,
            "pooled small-m dispatch ({small_m_speedup:.3}x) failed to beat spawn-per-call"
        );
    }
    Ok(())
}
