//! Bench: the serving coordinator hot path — batcher+router+dispatch
//! overhead with an instant backend (isolates L3 from model compute), and
//! closed-loop throughput with the simulator-paced backend. Both run
//! through the unified `InferenceBackend` trait.
//!
//! §Perf target: coordinator overhead p50 < 200 µs/request at load.

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{EchoBackend, InferenceBackend, SimBackend, Value};
use s4::coordinator::{BatcherConfig, Router, RoutingPolicy, Server, ServerConfig};
use s4::runtime::Manifest;
use s4::util::stats::Summary;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

fn run_closed_loop(backend: Arc<dyn InferenceBackend>, n: usize, label: &str) {
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            workers: 4,
            max_inflight: 4096,
            ..Default::default()
        },
        manifest(),
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n)
        .filter_map(|i| h.submit("bert_tiny", vec![Value::tokens(vec![i as i32; 32])]).ok())
        .collect();
    let mut lat_us = Vec::with_capacity(tickets.len());
    for t in tickets {
        let r = t.wait_timeout(Duration::from_secs(60)).expect("response");
        assert!(r.is_ok());
        lat_us.push(r.latency_us as f64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::of(&lat_us);
    println!(
        "bench {label:<40} {:>9.0} req/s  lat p50 {:>8.0}µs p99 {:>8.0}µs  fill {:.2}",
        lat_us.len() as f64 / wall,
        s.p50,
        s.p99,
        h.metrics.mean_batch_fill(),
    );
    srv.shutdown();
}

fn main() {
    // coordinator overhead: instant echo backend, open-loop burst
    let m = manifest();
    run_closed_loop(
        Arc::new(EchoBackend::from_manifest(&m)),
        20_000,
        "coordinator_overhead(echo backend)",
    );
    // simulator-paced: batching actually matters
    run_closed_loop(
        Arc::new(SimBackend::from_manifest(&m, 0.05)),
        2_000,
        "closed_loop(sim-paced backend, 5% scale)",
    );
}
