//! Bench: the simulation engine itself — §Perf target: ≥1M processed
//! task-events/s on the event core, and the analytic engine fast enough
//! for thousand-point sweeps.

use s4::arch::{AntoumConfig, EventSim, ResourceId};
use s4::graph::models;
use s4::sim::{simulate, simulate_event, Parallelism, Target};
use s4::sparse::tensor::DType;
use s4::util::bench::Bench;

fn main() {
    let b = Bench::default();
    let cfg = AntoumConfig::s4();

    // raw event core: layered DAG, 16 resources, 20k tasks
    let build = || {
        let mut sim = EventSim::new(16);
        let mut prev = Vec::new();
        for layer in 0..200 {
            let mut cur = Vec::new();
            for i in 0..100 {
                let deps: Vec<_> = if prev.is_empty() {
                    vec![]
                } else {
                    vec![prev[i % prev.len()]]
                };
                cur.push(sim.add_task(
                    ResourceId((layer * 7 + i) % 16),
                    1e-6 * ((i % 13) as f64 + 1.0),
                    &deps,
                    0,
                ));
            }
            prev = cur;
        }
        sim
    };
    let sim = build();
    let (_, eps) = b.run_throughput("event_core 20k tasks/16 res", || {
        let t = sim.run();
        std::hint::black_box(t.events_processed)
    });
    println!(
        "  events/s: {:.2}M {}",
        eps / 1e6,
        if eps >= 1e6 { "— §Perf target met" } else { "— BELOW 1M target" }
    );

    // analytic engine on the real graphs
    let bert = models::bert(models::BERT_BASE, 16, 128);
    let resnet = models::resnet50(16, 224);
    b.run("analytic bert_base", || {
        std::hint::black_box(simulate(&bert, Target::antoum(&cfg, 8)));
    });
    b.run("analytic resnet50", || {
        std::hint::black_box(simulate(&resnet, Target::antoum(&cfg, 8)));
    });

    // full event-mode model simulation (includes graph fusion + task build)
    b.run("event bert_base data-parallel", || {
        std::hint::black_box(simulate_event(
            &bert,
            &cfg,
            8,
            DType::Int8,
            Parallelism::DataParallel,
        ));
    });
    b.run("event bert_base 4-stage pipeline x8", || {
        std::hint::black_box(simulate_event(
            &bert,
            &cfg,
            8,
            DType::Int8,
            Parallelism::ModelParallel { stages: 4, inflight: 8 },
        ));
    });
}
