//! Bench: QoS-aware serving under mixed-priority overload, with a
//! machine-readable perf trajectory.
//!
//! Emits `BENCH_qos.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf "QoS"): the same burst workload is served twice through the
//! coordinator over a fixed-service-time backend —
//!
//! * **fifo_baseline** — every request `Standard` (the undifferentiated
//!   PR 1-era behavior): latency is queue position, the tail is the whole
//!   backlog drain;
//! * **qos** — the identical arrival sequence tagged `Interactive` /
//!   `Standard` / `Bulk`, bulk carrying a deadline it cannot meet at the
//!   back of the queue, plus a slice of explicit ticket cancellations.
//!
//! The trajectory point each PR defends: `interactive_p99_speedup_vs_fifo`
//! strictly > 1 (priority scheduling must buy the latency-critical class
//! real tail latency under overload) while expired/cancelled work is shed
//! before it reaches the backend (`shed_rate` > 0, zero backend time
//! spent on it).
//!
//! ```bash
//! cargo bench --bench serving_qos            # full
//! cargo bench --bench serving_qos -- --smoke # CI trajectory point
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{EchoBackend, InferenceBackend, TensorSpec, Value};
use s4::coordinator::{
    BatcherConfig, Priority, ResponseStatus, Router, RoutingPolicy, Server, ServerConfig,
    SubmitOptions, Ticket,
};
use s4::runtime::Manifest;
use s4::util::bench::JsonReport;
use s4::util::cli::Args;
use s4::util::json::Json;
use s4::util::stats::Summary;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

/// Echo semantics with a fixed service time per batch — a backend slow
/// enough to build a real backlog, deterministic enough for a
/// trajectory point.
struct ThrottledEcho {
    inner: EchoBackend,
    service: Duration,
}

impl InferenceBackend for ThrottledEcho {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.input_specs(artifact)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.output_specs(artifact)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        std::thread::sleep(self.service);
        self.inner.run_batch(artifact, inputs)
    }
}

/// The class each burst position gets in the qos scenario (the baseline
/// serves the identical positions as all-Standard). Every 5th request is
/// interactive; 2 in 10 are bulk.
fn class_of(i: usize) -> Priority {
    match i % 10 {
        0 | 5 => Priority::Interactive,
        3 | 8 => Priority::Bulk,
        _ => Priority::Standard,
    }
}

struct RunOutcome {
    /// completed latencies (µs) per class
    lat_us: [Vec<f64>; 3],
    expired: u64,
    cancelled: u64,
    admitted: u64,
    wall_s: f64,
}

/// Burst-submit `n` requests and wait for every ticket. In qos mode
/// requests are tagged by [`class_of`], bulk carries `bulk_deadline`,
/// and the last few standard tickets are cancelled while queued.
fn run_burst(n: usize, service: Duration, qos: bool, bulk_deadline: Duration) -> RunOutcome {
    let m = manifest();
    let backend = Arc::new(ThrottledEcho { inner: EchoBackend::from_manifest(&m), service });
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(500) },
            workers: 1,
            max_inflight: 4 * n, // admission out of the picture: this bench measures scheduling
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let t0 = Instant::now();
    let cancel_from = n.saturating_sub(n / 10); // last 10%: cancelled while queued
    let mut tickets: Vec<Ticket> = Vec::with_capacity(n);
    for i in 0..n {
        let opts = if qos {
            match class_of(i) {
                Priority::Interactive => SubmitOptions::interactive(),
                Priority::Bulk => SubmitOptions::bulk().with_deadline(bulk_deadline),
                Priority::Standard => SubmitOptions::default(),
            }
        } else {
            SubmitOptions::default()
        };
        let t = h
            .submit_with("bert_tiny", vec![Value::tokens(vec![i as i32 % 997; 32])], opts)
            .expect("burst fits under max_inflight");
        tickets.push(t);
    }
    if qos {
        for t in &tickets[cancel_from..] {
            if t.priority() == Priority::Standard {
                t.cancel();
            }
        }
    }
    let mut lat_us: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for (i, t) in tickets.iter().enumerate() {
        let r = t.wait_timeout(Duration::from_secs(120)).expect("response");
        match r.status {
            ResponseStatus::Ok => {
                // in the baseline, bucket by the class the request WOULD
                // have (same positions), so the two runs compare the same
                // subpopulation
                lat_us[class_of(i).idx()].push(r.latency_us as f64);
            }
            ResponseStatus::Expired | ResponseStatus::Cancelled => {
                assert!(qos, "baseline run must not shed");
                assert!(r.outputs.is_empty(), "shed work must never reach the backend");
            }
            ResponseStatus::Error(e) => panic!("request failed: {e}"),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = h.metrics_snapshot();
    assert_eq!(
        snap.answered(),
        snap.admitted,
        "every admitted request answered exactly once: {}",
        snap.report()
    );
    srv.shutdown();
    RunOutcome {
        lat_us,
        expired: snap.expired,
        cancelled: snap.cancelled,
        admitted: snap.admitted,
        wall_s,
    }
}

fn class_entry(scenario: &str, class: Priority, lat: &[f64]) -> Json {
    let (p50, p99) = if lat.is_empty() {
        (0.0, 0.0)
    } else {
        let s = Summary::of(lat);
        (s.p50, s.p99)
    };
    println!(
        "bench qos/{scenario:<14} {:<12} n={:<5} p50 {p50:>9.0}µs  p99 {p99:>9.0}µs",
        class.as_str(),
        lat.len()
    );
    Json::obj(vec![
        ("scenario", Json::Str(scenario.into())),
        ("class", Json::Str(class.as_str().into())),
        ("completed", Json::Num(lat.len() as f64)),
        ("p50_us", Json::Num(p50)),
        ("p99_us", Json::Num(p99)),
    ])
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let (n, service) = if smoke {
        (400, Duration::from_micros(300))
    } else {
        (2_000, Duration::from_millis(1))
    };
    // a bulk deadline around a third of the expected drain time: the bulk
    // tail cannot meet it from the back of the priority queue and is shed
    let drain = service * (n as u32 / 8);
    let bulk_deadline = drain / 3;

    println!("== serving qos ({n} requests, {service:?}/batch, bulk deadline {bulk_deadline:?}) ==");
    let baseline = run_burst(n, service, false, bulk_deadline);
    let qos = run_burst(n, service, true, bulk_deadline);

    let mut report = JsonReport::new("qos");
    report.set("smoke", Json::Bool(smoke));
    // QoS uses a synthetic-delay backend behind one coordinator worker
    // (see run_burst's ServerConfig); that is the effective parallelism
    report.set_effective_workers(1);
    report.set("requests", Json::Num(n as f64));
    report.set("service_us_per_batch", Json::Num(service.as_micros() as f64));
    report.set("bulk_deadline_us", Json::Num(bulk_deadline.as_micros() as f64));

    for p in Priority::ALL {
        report.push(class_entry("fifo_baseline", p, &baseline.lat_us[p.idx()]));
    }
    for p in Priority::ALL {
        report.push(class_entry("qos", p, &qos.lat_us[p.idx()]));
    }

    // the headline ratio: tail latency of the interactive positions under
    // priority scheduling vs the SAME positions under undifferentiated FIFO
    let base_int = Summary::of(&baseline.lat_us[Priority::Interactive.idx()]);
    let qos_int_lat = &qos.lat_us[Priority::Interactive.idx()];
    anyhow::ensure!(!qos_int_lat.is_empty(), "interactive class must complete");
    let qos_int = Summary::of(qos_int_lat);
    let speedup = base_int.p99 / qos_int.p99.max(1.0);
    let shed = qos.expired + qos.cancelled;
    let shed_rate = shed as f64 / qos.admitted as f64;
    report.set("interactive_p99_speedup_vs_fifo", Json::Num(speedup));
    report.set("shed_rate", Json::Num(shed_rate));
    report.set("expired", Json::Num(qos.expired as f64));
    report.set("cancelled", Json::Num(qos.cancelled as f64));
    report.set("baseline_wall_s", Json::Num(baseline.wall_s));
    report.set("qos_wall_s", Json::Num(qos.wall_s));

    println!(
        "bench qos/summary        interactive p99 {:.0}µs vs fifo {:.0}µs  \
         speedup {speedup:.2}x  shed {shed} ({:.1}%: {} expired, {} cancelled)",
        qos_int.p99,
        base_int.p99,
        100.0 * shed_rate,
        qos.expired,
        qos.cancelled
    );
    anyhow::ensure!(
        speedup > 1.0,
        "QoS scheduling must beat undifferentiated FIFO for the interactive tail \
         (got {speedup:.2}x)"
    );
    anyhow::ensure!(
        baseline.expired == 0 && baseline.cancelled == 0,
        "baseline must not shed"
    );
    anyhow::ensure!(qos.expired > 0, "overloaded bulk tail must expire");
    anyhow::ensure!(qos.cancelled > 0, "cancelled tickets must be shed");

    let path = report.write()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
