//! Bench: regenerate paper **Figure 2** — speedup (throughput) on S4 at
//! sparsity ∈ {1..32} for ResNet-50 and BERT-base, with the T4 reference —
//! and time the simulator doing it (the sweep is the workload the analytic
//! engine must sustain).
//!
//! `cargo bench --bench fig2_speedup` (add `-- --ablate-t4-eff` to sweep
//! the T4 efficiency assumption, `-- --ablate-overhead` for the SPU tile
//! overhead ablation DESIGN.md calls out).

use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::t4::T4Config;
use s4::sim::{report, simulate, Target};
use s4::sparse::tensor::DType;
use s4::util::bench::Bench;
use s4::util::cli::Args;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = AntoumConfig::s4();
    let batch = 16;
    let resnet = models::resnet50(batch, 224);
    let bert = models::bert(models::BERT_BASE, batch, 128);

    // ---- the table itself ----
    let base_r = simulate(&resnet, Target::antoum(&cfg, 1)).throughput;
    let base_b = simulate(&bert, Target::antoum(&cfg, 1)).throughput;
    let mut rows = Vec::new();
    for &s in &s4::sparse::SUPPORTED_SPARSITIES {
        let tr = simulate(&resnet, Target::antoum(&cfg, s)).throughput;
        let tb = simulate(&bert, Target::antoum(&cfg, s)).throughput;
        rows.push(report::Fig2Row {
            sparsity: s,
            resnet50_tput: tr,
            resnet50_speedup: tr / base_r,
            bert_tput: tb,
            bert_speedup: tb / base_b,
        });
    }
    let t4r = simulate(&resnet, Target::t4()).throughput;
    let t4b = simulate(&bert, Target::t4()).throughput;
    print!("{}", report::fig2_table(&rows, t4r, t4b));

    // ---- harness timing: one full sweep ----
    let b = Bench::default();
    b.run("fig2_full_sweep(12 sims)", || {
        for &s in &s4::sparse::SUPPORTED_SPARSITIES {
            std::hint::black_box(simulate(&resnet, Target::antoum(&cfg, s)));
            std::hint::black_box(simulate(&bert, Target::antoum(&cfg, s)));
        }
    });

    // ---- ablations ----
    if args.has("ablate-t4-eff") {
        println!("\nT4 GEMM-efficiency ablation (ResNet-50 reference line):");
        for eff in [0.25, 0.35, 0.50] {
            let t4 = T4Config { eff_gemm: eff, ..T4Config::t4() };
            let r = simulate(&resnet, Target::T4 { cfg: t4, dtype: DType::Int8 });
            println!("  eff_gemm={eff:.2}: {:>8.0} img/s", r.throughput);
        }
    }
    if args.has("ablate-overhead") {
        println!("\nSPU tile-overhead ablation (ResNet-50 speedup at 32x):");
        for ovh in [0.0, 8.0, 64.0, 256.0] {
            let mut c = cfg.clone();
            c.spu_tile_overhead_cycles = ovh;
            let b1 = simulate(&resnet, Target::antoum(&c, 1)).throughput;
            let b32 = simulate(&resnet, Target::antoum(&c, 32)).throughput;
            println!("  overhead={ovh:>5.0} cyc: {:.1}x", b32 / b1);
        }
    }
}
