//! Bench: response cache + single-flight coalescing under Zipf-skewed
//! open-loop traffic, with a machine-readable hit-rate/throughput
//! trajectory.
//!
//! Emits `BENCH_cache.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Perf): one fixed-service-time backend, offered load pinned at ~2×
//! backend capacity, payload keys drawn from a seeded [`Zipf`] over a
//! small universe — the heavy-tailed shape of hot-input traffic from a
//! large user population. The sweep crosses skew (`s = 0.6` mild,
//! `s = 1.1` classic web skew) with cache off/on.
//!
//! Trajectory points each PR defends (at `s = 1.1`, cache on):
//! * hit-path p50 < miss-path p50 — a hit must actually be faster than
//!   going through the batcher and backend;
//! * achieved throughput at the same offered load rises vs cache-off
//!   (ratio > 1.05) — hits return compute to the misses;
//! * accounting: `admitted + cache_hits + coalesced == n` and
//!   `answered() == admitted` — nothing double-counted, nothing lost;
//! * exactness: a repeated payload's cached logits are bitwise-identical
//!   to the miss that populated them.
//!
//! ```bash
//! cargo bench --bench cache_hit_rate            # full
//! cargo bench --bench cache_hit_rate -- --smoke # CI trajectory point
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{EchoBackend, InferenceBackend, TensorSpec, Value};
use s4::coordinator::{
    BatcherConfig, CacheConfig, Router, RoutingPolicy, Server, ServerConfig, ServerHandle, Ticket,
};
use s4::runtime::Manifest;
use s4::util::bench::JsonReport;
use s4::util::cli::Args;
use s4::util::json::Json;
use s4::util::rng::Xoshiro256;
use s4::util::stats::{Summary, Zipf};

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

/// Echo with a fixed service time, so backend capacity is exact
/// (`workers / service`) and the hit-vs-miss latency gap is real compute
/// avoided, not scheduler noise.
struct ThrottledEcho {
    inner: EchoBackend,
    service: Duration,
}

impl InferenceBackend for ThrottledEcho {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.input_specs(artifact)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.output_specs(artifact)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        std::thread::sleep(self.service);
        self.inner.run_batch(artifact, inputs)
    }
}

/// Deterministic payload for hot-key rank `k` (32 tokens).
fn payload(k: usize) -> Vec<Value> {
    let tokens: Vec<i32> = (0..32).map(|t| ((k * 131 + t * 7) % 997) as i32).collect();
    vec![Value::tokens(tokens)]
}

struct RunOutcome {
    achieved_rps: f64,
    hit_p50_us: f64,
    miss_p50_us: f64,
    hits: u64,
    coalesced: u64,
    admitted: u64,
    hit_rate: f64,
}

/// One open-loop run: `n` arrivals at `rate` rps, keys Zipf(s)-sampled
/// over `universe` hot payloads. Latency is measured from *scheduled*
/// arrival time; pending tickets are harvested concurrently with the
/// send loop so an already-answered cache hit is observed promptly, not
/// after the whole schedule has been sent.
fn run_once(
    n: usize,
    rate: f64,
    service: Duration,
    universe: usize,
    s: f64,
    cache: Option<CacheConfig>,
) -> anyhow::Result<RunOutcome> {
    let m = manifest();
    let backend: Arc<dyn InferenceBackend> =
        Arc::new(ThrottledEcho { inner: EchoBackend::from_manifest(&m), service });
    let cache_on = cache.is_some();
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(300) },
            workers: 2,
            max_inflight: 4 * n,
            cache,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h: ServerHandle = srv.handle();

    let zipf = Zipf::new(universe, s);
    let mut rng = Xoshiro256::seed_from_u64(0xCACE + (s * 1000.0) as u64);
    let interval = Duration::from_secs_f64(1.0 / rate);
    // (scheduled arrival, ticket) still awaiting a response
    let mut pending: Vec<(Instant, Ticket)> = Vec::with_capacity(n);
    let mut hit_us: Vec<f64> = Vec::new();
    let mut miss_us: Vec<f64> = Vec::new();

    // classify and record one completed response
    let mut record = |due: Instant, served_by: &str, now: Instant| {
        let lat = now.saturating_duration_since(due).as_secs_f64() * 1e6;
        if served_by.starts_with("cache:") {
            hit_us.push(lat);
        } else {
            miss_us.push(lat);
        }
    };

    let start = Instant::now();
    for i in 0..n {
        let due = start + interval.mul_f64(i as f64);
        // harvest completions while waiting for this arrival's slot
        loop {
            let now = Instant::now();
            if now >= due {
                break;
            }
            let mut progressed = false;
            pending.retain(|(d, t)| match t.try_take() {
                Ok(Some(r)) => {
                    record(*d, &r.served_by, Instant::now());
                    progressed = true;
                    false
                }
                _ => true,
            });
            if !progressed {
                let nap = due.saturating_duration_since(Instant::now()).min(Duration::from_micros(50));
                if !nap.is_zero() {
                    std::thread::sleep(nap);
                }
            }
        }
        let k = zipf.sample(&mut rng);
        let t = h
            .submit("bert_tiny", payload(k))
            .map_err(|d| anyhow::anyhow!("open-loop arrival rejected: {d:?}"))?;
        pending.push((due, t));
    }
    // drain the tail
    for (due, t) in &pending {
        let r = t.wait_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(r.is_ok(), "request failed: {:?}", r.status);
        record(*due, &r.served_by, Instant::now());
    }
    let elapsed = start.elapsed().as_secs_f64();

    let snap = h.metrics_snapshot();
    let inflight = h.inflight();
    srv.shutdown();

    anyhow::ensure!(
        snap.answered() == snap.admitted,
        "core invariant violated: {}",
        snap.report()
    );
    anyhow::ensure!(
        snap.admitted + snap.cache_hits + snap.coalesced == n as u64,
        "every arrival is admitted, a hit, or coalesced: {}",
        snap.report()
    );
    anyhow::ensure!(inflight == 0, "leaked admission slots: {inflight}");
    anyhow::ensure!(
        cache_on || (snap.cache_hits == 0 && snap.coalesced == 0),
        "cache-off run recorded cache traffic: {}",
        snap.report()
    );
    anyhow::ensure!(
        hit_us.len() as u64 == snap.cache_hits,
        "served_by 'cache:' marks exactly the hits: {} observed vs {} counted",
        hit_us.len(),
        snap.cache_hits
    );

    let p50 = |xs: &Vec<f64>| if xs.is_empty() { 0.0 } else { Summary::of(xs).p50 };
    Ok(RunOutcome {
        achieved_rps: n as f64 / elapsed,
        hit_p50_us: p50(&hit_us),
        miss_p50_us: p50(&miss_us),
        hits: snap.cache_hits,
        coalesced: snap.coalesced,
        admitted: snap.admitted,
        hit_rate: (snap.cache_hits + snap.coalesced) as f64 / n as f64,
    })
}

/// Sequential exactness probe: the same payload twice through a
/// cache-enabled stack must hit, be marked, and return bitwise-identical
/// logits.
fn exactness_probe() -> anyhow::Result<()> {
    let m = manifest();
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
            workers: 1,
            max_inflight: 8,
            cache: Some(CacheConfig::default()),
            ..Default::default()
        },
        m.clone(),
        Router::new(RoutingPolicy::MaxSparsity),
        Arc::new(EchoBackend::from_manifest(&m)),
    );
    let h = srv.handle();
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let first = h.submit("bert_tiny", payload(3)).unwrap().wait_timeout(Duration::from_secs(30))?;
    anyhow::ensure!(first.is_ok(), "miss must serve: {:?}", first.status);
    let second = h.submit("bert_tiny", payload(3)).unwrap().wait_timeout(Duration::from_secs(30))?;
    anyhow::ensure!(second.is_ok(), "hit must serve: {:?}", second.status);
    anyhow::ensure!(
        second.served_by.starts_with("cache:"),
        "repeat must be served by the cache, got {:?}",
        second.served_by
    );
    anyhow::ensure!(
        bits(first.logits()) == bits(second.logits()),
        "cached logits must be bitwise-identical to the miss that populated them"
    );
    srv.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let (n, service, universe) = if smoke {
        (2_000, Duration::from_micros(300), 48)
    } else {
        (12_000, Duration::from_micros(400), 64)
    };
    let workers = 2.0;
    let capacity_rps = workers / service.as_secs_f64();
    let rate = 2.0 * capacity_rps; // pinned past saturation: misses queue

    exactness_probe()?;
    println!("== cache hit rate (n={n}, {service:?}/call, offered {rate:.0} rps ≈ 2× capacity) ==");

    let mut report = JsonReport::new("cache");
    report.set("smoke", Json::Bool(smoke));
    report.set_effective_workers(2);
    report.set("requests_per_run", Json::Num(n as f64));
    report.set("service_us_per_call", Json::Num(service.as_micros() as f64));
    report.set("key_universe", Json::Num(universe as f64));
    report.set("offered_rps", Json::Num(rate));

    // the headline comparison: classic web skew, cache off vs on
    let mut headline: Option<(RunOutcome, RunOutcome)> = None;
    for &s in &[0.6, 1.1] {
        let off = run_once(n, rate, service, universe, s, None)?;
        let on = run_once(n, rate, service, universe, s, Some(CacheConfig::default()))?;
        let ratio = on.achieved_rps / off.achieved_rps;
        println!(
            "bench cache/zipf{s:.1}  off {:>7.0} rps | on {:>7.0} rps (×{ratio:.2})  \
             hit_rate {:.0}% ({} hits + {} coalesced)  hit p50 {:>6.0}µs vs miss p50 {:>8.0}µs",
            off.achieved_rps,
            on.achieved_rps,
            on.hit_rate * 100.0,
            on.hits,
            on.coalesced,
            on.hit_p50_us,
            on.miss_p50_us,
        );
        report.push(Json::obj(vec![
            ("zipf_s", Json::Num(s)),
            ("off_achieved_rps", Json::Num(off.achieved_rps)),
            ("on_achieved_rps", Json::Num(on.achieved_rps)),
            ("throughput_ratio", Json::Num(ratio)),
            ("hit_rate", Json::Num(on.hit_rate)),
            ("cache_hits", Json::Num(on.hits as f64)),
            ("coalesced", Json::Num(on.coalesced as f64)),
            ("admitted", Json::Num(on.admitted as f64)),
            ("hit_p50_us", Json::Num(on.hit_p50_us)),
            ("miss_p50_us", Json::Num(on.miss_p50_us)),
        ]));
        if s == 1.1 {
            headline = Some((off, on));
        }
    }

    let (off, on) = headline.expect("s=1.1 ran");
    let throughput_ratio = on.achieved_rps / off.achieved_rps;
    report.set("headline_zipf_s", Json::Num(1.1));
    report.set("headline_hit_rate", Json::Num(on.hit_rate));
    report.set("headline_hit_p50_us", Json::Num(on.hit_p50_us));
    report.set("headline_miss_p50_us", Json::Num(on.miss_p50_us));
    report.set("headline_throughput_ratio", Json::Num(throughput_ratio));

    // the contract this bench exists to defend
    anyhow::ensure!(on.hits > 0, "skewed traffic must produce resolved hits");
    anyhow::ensure!(
        on.hit_rate > 0.25,
        "zipf(1.1) hit rate {:.3} <= 0.25: the cache is not catching the hot keys",
        on.hit_rate
    );
    anyhow::ensure!(
        on.hit_p50_us < on.miss_p50_us,
        "hit-path p50 {:.0}µs must beat miss-path p50 {:.0}µs",
        on.hit_p50_us,
        on.miss_p50_us
    );
    anyhow::ensure!(
        throughput_ratio > 1.05,
        "cache-on throughput {:.0} rps must beat cache-off {:.0} rps by >5% at the same \
         offered load (ratio {throughput_ratio:.3})",
        on.achieved_rps,
        off.achieved_rps
    );

    let path = report.write()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
