//! Bench: serving throughput through a fault storm, with a
//! machine-readable recovery trajectory.
//!
//! Emits `BENCH_fault.json` (schema `s4-bench-v1`, see EXPERIMENTS.md
//! §Robustness): one serving stack, one run, three phases over a
//! fixed-service-time backend wrapped in a deterministic
//! [`FaultingBackend`] —
//!
//! * **pre** — clean burst, measuring baseline throughput;
//! * **storm** — scheduled worker-killing panics plus an error burst
//!   that trips the health breaker; goodput and typed-failure accounting
//!   are recorded while the supervisor respawns workers and the breaker
//!   sheds/probes;
//! * **post** — the identical clean burst again, after recovery.
//!
//! The trajectory point each PR defends:
//! `post_recovery_throughput_ratio` ≥ 0.9 — a storm may cost its own
//! window, but it must not permanently shrink capacity (leaked slots,
//! unreplaced workers, a stuck-open breaker would all show up here) —
//! with `worker_restarts` ≥ 1 proving the storm actually killed and
//! replaced workers rather than being absorbed trivially.
//!
//! ```bash
//! cargo bench --bench fault_recovery            # full
//! cargo bench --bench fault_recovery -- --smoke # CI trajectory point
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{EchoBackend, InferenceBackend, TensorSpec, Value};
use s4::coordinator::{
    AdmissionDecision, BatcherConfig, BreakerConfig, Router, RoutingPolicy, Server, ServerConfig,
    ServerHandle, Ticket,
};
use s4::fault::{FaultPlan, FaultingBackend};
use s4::runtime::Manifest;
use s4::util::bench::JsonReport;
use s4::util::cli::Args;
use s4::util::json::Json;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

/// Echo with a fixed service time, so throughput is service-bound and the
/// pre/post ratio is stable rather than scheduler noise.
struct ThrottledEcho {
    inner: EchoBackend,
    service: Duration,
}

impl InferenceBackend for ThrottledEcho {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.input_specs(artifact)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.output_specs(artifact)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        std::thread::sleep(self.service);
        self.inner.run_batch(artifact, inputs)
    }
}

/// Burst-submit `n` clean requests and wait for all; returns throughput
/// (completions/s). Used identically for the pre and post phases.
fn clean_burst(h: &ServerHandle, n: usize) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(n);
    for i in 0..n {
        tickets.push(
            h.submit("bert_tiny", vec![Value::tokens(vec![i as i32 % 997; 32])])
                .map_err(|d| anyhow::anyhow!("clean burst rejected: {d:?}"))?,
        );
    }
    for t in &tickets {
        let r = t.wait_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(r.is_ok(), "clean burst request failed: {:?}", r.status);
    }
    Ok(n as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.has("smoke")
        || std::env::var("S4_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    let (n, service) = if smoke {
        (150, Duration::from_micros(200))
    } else {
        (800, Duration::from_micros(500))
    };

    // storm scheduled by backend call index: the pre burst consumes
    // exactly `n` calls (max_batch 1 → one call per request), then two
    // worker-killing panics and an error burst long enough to trip the
    // breaker even counting from zero
    let breaker =
        BreakerConfig { failure_threshold: 4, probe_after_sheds: 2, close_after_probes: 2 };
    let storm_start = n as u64;
    let plan = FaultPlan::new()
        .with_panic_at(storm_start)
        .with_panic_at(storm_start + 1)
        .with_error_burst(storm_start + 2, 4);
    let storm_len = plan.len() as u64;

    let m = manifest();
    let throttled: Arc<dyn InferenceBackend> =
        Arc::new(ThrottledEcho { inner: EchoBackend::from_manifest(&m), service });
    // keep a typed handle for injection accounting; the server gets a clone
    let faulting = Arc::new(FaultingBackend::new(throttled, plan));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(500) },
            workers: 2,
            max_inflight: 4 * n,
            breaker,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        faulting.clone(),
    );
    let h = srv.handle();

    println!("== fault recovery ({n} requests/phase, {service:?}/call, storm {storm_len} faults) ==");
    let pre_rps = clean_burst(&h, n)?;
    println!("bench fault/pre            {pre_rps:>8.0} req/s clean");

    // the storm: one request at a time until every scheduled fault has
    // actually fired; breaker sheds are retried (they consume no call)
    let t_storm = Instant::now();
    let (mut storm_ok, mut storm_failed, mut storm_shed) = (0u64, 0u64, 0u64);
    loop {
        let (p, e, s) = faulting.injected();
        if p + e + s >= storm_len {
            break;
        }
        anyhow::ensure!(
            t_storm.elapsed() < Duration::from_secs(60),
            "storm never drained: {:?} of {storm_len} faults fired",
            faulting.injected()
        );
        match h.submit("bert_tiny", vec![Value::tokens(vec![1; 32])]) {
            Ok(t) => {
                let r = t.wait_timeout(Duration::from_secs(120))?;
                if r.is_ok() {
                    storm_ok += 1;
                } else {
                    storm_failed += 1;
                }
            }
            Err(AdmissionDecision::RejectUnhealthy(_)) => storm_shed += 1,
            Err(d) => anyhow::bail!("unexpected rejection during the storm: {d:?}"),
        }
    }
    // recovery: first clean completion after the last fault fired
    let t_recover = Instant::now();
    loop {
        anyhow::ensure!(
            t_recover.elapsed() < Duration::from_secs(60),
            "stack never recovered after the storm"
        );
        match h.submit("bert_tiny", vec![Value::tokens(vec![2; 32])]) {
            Ok(t) => {
                if t.wait_timeout(Duration::from_secs(120))?.is_ok() {
                    break;
                }
            }
            Err(AdmissionDecision::RejectUnhealthy(_)) => storm_shed += 1,
            Err(d) => anyhow::bail!("unexpected rejection during recovery: {d:?}"),
        }
    }
    let recovery_ms = t_recover.elapsed().as_secs_f64() * 1e3;
    let storm_attempts = storm_ok + storm_failed + storm_shed;
    let goodput = storm_ok as f64 / (storm_attempts.max(1)) as f64;
    println!(
        "bench fault/storm          {storm_attempts} attempts: {storm_ok} ok, \
         {storm_failed} typed failures, {storm_shed} breaker sheds  \
         goodput {:.0}%  recovery {recovery_ms:.1}ms",
        goodput * 100.0
    );

    let post_rps = clean_burst(&h, n)?;
    let ratio = post_rps / pre_rps;
    println!("bench fault/post           {post_rps:>8.0} req/s clean  ratio {ratio:.3}");

    let snap = h.metrics_snapshot();
    let inflight = h.inflight();
    srv.shutdown();

    let mut report = JsonReport::new("fault");
    report.set("smoke", Json::Bool(smoke));
    report.set_effective_workers(2);
    report.set("requests_per_phase", Json::Num(n as f64));
    report.set("service_us_per_call", Json::Num(service.as_micros() as f64));
    report.set("storm_faults", Json::Num(storm_len as f64));
    report.set("pre_throughput_rps", Json::Num(pre_rps));
    report.set("post_throughput_rps", Json::Num(post_rps));
    report.set("post_recovery_throughput_ratio", Json::Num(ratio));
    report.set("storm_goodput", Json::Num(goodput));
    report.set("storm_breaker_sheds", Json::Num(storm_shed as f64));
    report.set("recovery_ms", Json::Num(recovery_ms));
    report.set("worker_panics", Json::Num(snap.worker_panics as f64));
    report.set("worker_restarts", Json::Num(snap.worker_restarts as f64));
    report.set("breaker_opens", Json::Num(snap.breaker_opens as f64));

    // the contract this bench exists to defend
    anyhow::ensure!(
        ratio >= 0.9,
        "post-recovery throughput ratio {ratio:.3} < 0.9: the storm permanently \
         degraded the stack (pre {pre_rps:.0} vs post {post_rps:.0} req/s)"
    );
    anyhow::ensure!(
        snap.worker_restarts >= 1,
        "the storm must actually kill and respawn a worker: {}",
        snap.report()
    );
    anyhow::ensure!(snap.worker_panics >= 1, "{}", snap.report());
    anyhow::ensure!(snap.breaker_opens >= 1, "the error burst must trip the breaker");
    anyhow::ensure!(
        snap.answered() == snap.admitted,
        "no ticket lost through the storm: {}",
        snap.report()
    );
    anyhow::ensure!(inflight == 0, "leaked admission slots: {inflight}");

    let path = report.write()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
