//! End-to-end: the network front end over the *real* serving stack —
//! BERT-family requests through [`NetClient`] → TCP loopback →
//! [`NetServer`] → coordinator → [`CpuSparseBackend`] tiled sparse
//! compute → back over the wire. The headline invariant: logits served
//! over the socket are **bitwise identical** to direct in-process
//! submission, so the wire is a transparent transport, not a lossy one.
//! Recorded in EXPERIMENTS.md §E2E.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{CpuSparseBackend, Value};
use s4::coordinator::{
    AdmissionDecision, BatcherConfig, CacheConfig, Metrics, MetricsSnapshot, Router,
    RoutingPolicy, Server, ServerConfig, ServerHandle, ServingService, SubmitOptions, Ticket,
};
use s4::net::{
    read_frame, Frame, NetClient, NetServer, NetServerConfig, ReadEvent, WireStatus, MAGIC,
    MAX_FRAME_BYTES,
};
use s4::runtime::Manifest;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b4", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 4, "seq": 16,
       "inputs": [{"name": "ids", "shape": [4, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

fn server(m: Manifest) -> Server {
    let backend = Arc::new(CpuSparseBackend::from_manifest(&m));
    Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            max_inflight: 64,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    )
}

fn tokens(seed: i32) -> Vec<i32> {
    (0..16).map(|t| (seed * 31 + t * 7) % 997).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn logits_over_the_socket_are_bitwise_identical_to_direct_submission() {
    let srv = server(manifest());
    let handle = Arc::new(srv.handle());

    // direct, in-process
    let ids = tokens(11);
    let t = handle.submit("bert_tiny", vec![Value::tokens(ids.clone())]).unwrap();
    let direct = t.wait_timeout(Duration::from_secs(10)).unwrap();
    assert!(direct.is_ok(), "{:?}", direct.status);
    let direct_logits = direct.logits().to_vec();

    // same payload over TCP loopback
    let net =
        NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap();
    let mut c = NetClient::connect(net.local_addr(), Duration::from_secs(10)).unwrap();
    let r = c.call("bert_tiny", vec![Value::tokens(ids)]).unwrap();
    assert!(r.is_ok(), "{:?}", r.status);
    assert!(!r.served_by.is_empty(), "response carries the serving artifact");
    assert_eq!(
        bits(r.logits()),
        bits(&direct_logits),
        "socket logits must be bit-for-bit the in-process logits"
    );

    net.shutdown();
    srv.shutdown();
}

#[test]
fn cache_hits_are_transparent_over_the_wire() {
    // The response cache sits below the socket boundary: a remote client
    // repeating a payload gets a cache hit whose logits are bitwise
    // identical to the executed response, distinguishable only by the
    // `cache:`-prefixed served_by marker — the wire protocol needs no
    // changes and no client cooperation.
    let m = manifest();
    let backend = Arc::new(CpuSparseBackend::from_manifest(&m));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            max_inflight: 64,
            cache: Some(CacheConfig::default()),
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let handle = Arc::new(srv.handle());
    let net =
        NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap();
    let mut c = NetClient::connect(net.local_addr(), Duration::from_secs(10)).unwrap();

    let ids = tokens(21);
    let first = c.call("bert_tiny", vec![Value::tokens(ids.clone())]).unwrap();
    assert!(first.is_ok(), "{:?}", first.status);
    assert!(
        !first.served_by.starts_with("cache:"),
        "first submission must execute, served_by {:?}",
        first.served_by
    );

    let second = c.call("bert_tiny", vec![Value::tokens(ids)]).unwrap();
    assert!(second.is_ok(), "{:?}", second.status);
    assert!(
        second.served_by.starts_with("cache:"),
        "repeat payload must be served from cache, served_by {:?}",
        second.served_by
    );
    assert_eq!(
        bits(second.logits()),
        bits(first.logits()),
        "cached logits over the wire must be bit-for-bit the executed logits"
    );

    let snap = handle.metrics_snapshot();
    assert_eq!(snap.cache_hits, 1, "{}", snap.report());
    assert_eq!(snap.admitted, 1, "the hit must not re-execute: {}", snap.report());

    net.shutdown();
    srv.shutdown();
}

#[test]
fn pipelined_mixed_priorities_correlate_by_id_not_arrival_order() {
    let srv = server(manifest());
    let handle = Arc::new(srv.handle());
    let net =
        NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap();
    let mut c = NetClient::connect(net.local_addr(), Duration::from_secs(10)).unwrap();

    // ground truth per payload, computed in-process
    let expect = |seed: i32| {
        let t = handle.submit("bert_tiny", vec![Value::tokens(tokens(seed))]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        bits(r.logits())
    };
    let want = [expect(1), expect(2), expect(3)];

    // 9 requests in flight at once on one connection, classes cycling;
    // responses may arrive in any order and must re-associate by id
    let classes = [
        SubmitOptions::interactive(),
        SubmitOptions::default(),
        SubmitOptions::bulk(),
    ];
    let mut sent = Vec::new();
    for i in 0..9 {
        let seed = 1 + (i % 3) as i32;
        let id = c
            .send_with("bert_tiny", vec![Value::tokens(tokens(seed))], &classes[i / 3])
            .unwrap();
        sent.push((id, seed));
    }
    let mut got = 0;
    while got < sent.len() {
        let r = c.recv().unwrap();
        let (_, seed) = *sent.iter().find(|(id, _)| *id == r.id).expect("known id");
        assert!(r.is_ok(), "{:?}", r.status);
        assert_eq!(
            bits(r.logits()),
            want[(seed - 1) as usize],
            "response {} must carry the logits of the payload submitted under its id",
            r.id
        );
        got += 1;
    }

    net.shutdown();
    srv.shutdown();
}

/// Read server frames off a raw socket until it closes; returns the
/// statuses seen. Panics if the server neither answers nor closes.
fn drain_raw(stream: TcpStream) -> Vec<WireStatus> {
    stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    let mut r = BufReader::new(stream);
    let mut seen = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match read_frame(&mut r) {
            Ok(ReadEvent::Frame(Frame::Response(f))) => seen.push(f.status),
            Ok(ReadEvent::Frame(Frame::Request(_))) => panic!("server sent a request frame"),
            Ok(ReadEvent::Idle) => assert!(Instant::now() < deadline, "server never closed"),
            Ok(ReadEvent::Closed) => return seen,
            Err(e) => panic!("client-side read error: {e}"),
        }
    }
}

#[test]
fn malformed_bytes_close_only_the_offending_connection() {
    let srv = server(manifest());
    let handle = Arc::new(srv.handle());
    let net =
        NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap();
    let addr = net.local_addr();

    // a healthy connection opened *before* the attack…
    let mut healthy = NetClient::connect(addr, Duration::from_secs(10)).unwrap();
    assert!(healthy.call("bert_tiny", vec![Value::tokens(tokens(5))]).unwrap().is_ok());

    // …an HTTP client wanders in
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(b"GET / HTTP/1.1\r\nHost: s4\r\n\r\n").unwrap();
    raw.flush().unwrap();
    let seen = drain_raw(raw);
    assert!(
        seen.iter().any(|s| matches!(s, WireStatus::Rejected(_))),
        "malformed bytes must be answered with a Rejected frame, got {seen:?}"
    );

    // …and the healthy connection is untouched
    assert!(healthy.call("bert_tiny", vec![Value::tokens(tokens(6))]).unwrap().is_ok());
    let snap = net.metrics().snapshot();
    assert!(snap.net.frames_malformed >= 1, "{:?}", snap.net);
    assert!(snap.net.conns_closed_on_error >= 1, "{:?}", snap.net);

    net.shutdown();
    srv.shutdown();
}

#[test]
fn oversized_declared_length_closes_the_connection_before_allocation() {
    let srv = server(manifest());
    let handle = Arc::new(srv.handle());
    let net =
        NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap();
    let addr = net.local_addr();

    // a syntactically valid header declaring an absurd payload
    let mut raw = TcpStream::connect(addr).unwrap();
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC);
    hdr.push(1); // request
    hdr.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
    raw.write_all(&hdr).unwrap();
    raw.flush().unwrap();
    let seen = drain_raw(raw);
    assert!(
        seen.iter().any(|s| matches!(s, WireStatus::Rejected(_))),
        "oversized frame must be answered with a Rejected frame, got {seen:?}"
    );

    // the listener is still serving fresh connections
    let mut c = NetClient::connect(addr, Duration::from_secs(10)).unwrap();
    assert!(c.call("bert_tiny", vec![Value::tokens(tokens(7))]).unwrap().is_ok());

    net.shutdown();
    srv.shutdown();
}

/// Delegates to the real stack but panics *after* the inner submission
/// admitted a request — the nastiest spot for a handler panic, because a
/// leaked admission slot would wedge a `max_inflight = 1` server forever.
struct PanickyService {
    inner: Arc<ServerHandle>,
}

impl ServingService for PanickyService {
    fn submit_with(
        &self,
        model: &str,
        inputs: Vec<Value>,
        opts: SubmitOptions,
    ) -> Result<Ticket, AdmissionDecision> {
        if model == "boom" {
            // consume a slot for real, then die before returning the ticket
            let _ = self.inner.submit_with("bert_tiny", inputs, opts);
            panic!("handler blew up after admission");
        }
        self.inner.submit_with(model, inputs, opts)
    }

    fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.inner.metrics_snapshot()
    }

    fn shared_metrics(&self) -> Option<Arc<Metrics>> {
        self.inner.shared_metrics()
    }
}

#[test]
fn handler_panic_answers_an_error_and_does_not_leak_the_admission_slot() {
    // regression (ISSUE PR 6 satellite): a panicking connection handler
    // must neither kill the connection nor strand its admission slot
    let m = manifest();
    let backend = Arc::new(CpuSparseBackend::from_manifest(&m));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 1, // one leaked slot == a wedged server
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let svc = Arc::new(PanickyService { inner: Arc::new(srv.handle()) });
    let net = NetServer::bind("127.0.0.1:0", svc, NetServerConfig::default()).unwrap();
    let mut c = NetClient::connect(net.local_addr(), Duration::from_secs(10)).unwrap();

    let r = c.call("boom", vec![Value::tokens(tokens(9))]).unwrap();
    assert!(
        matches!(r.status, WireStatus::Error(_)),
        "panic must surface as an Error frame, got {:?}",
        r.status
    );

    // same connection; the orphaned request drains worker-side, freeing
    // the only slot — a follow-up must eventually be served
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = c.call("bert_tiny", vec![Value::tokens(tokens(9))]).unwrap();
        if r.is_ok() {
            break;
        }
        assert!(
            matches!(r.status, WireStatus::Rejected(_)),
            "only transient admission rejection is acceptable, got {:?}",
            r.status
        );
        assert!(
            Instant::now() < deadline,
            "admission slot leaked: server still rejecting 10s after the panic"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    net.shutdown();
    srv.shutdown();
}

#[test]
fn server_shutdown_drains_the_socket_front_end_via_the_hook() {
    let srv = server(manifest());
    let handle = Arc::new(srv.handle());
    let net = Arc::new(
        NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap(),
    );
    let addr = net.local_addr();
    {
        let net = net.clone();
        srv.on_shutdown(move || net.shutdown());
    }

    let mut c = NetClient::connect(addr, Duration::from_secs(10)).unwrap();
    assert!(c.call("bert_tiny", vec![Value::tokens(tokens(2))]).unwrap().is_ok());

    // ONE call tears down the whole stack, socket boundary first
    srv.shutdown();

    let after = NetClient::connect(addr, Duration::from_secs(1))
        .and_then(|mut c| c.call("bert_tiny", vec![Value::tokens(tokens(2))]));
    assert!(after.is_err(), "socket front end must be down after Server::shutdown");
}
