//! End-to-end: BERT-family requests served through [`CpuSparseBackend`]
//! via `Server::start` — the full coordinator path (admission → dynamic
//! batcher → router → spec-driven pack → **real tiled sparse compute** →
//! demux) producing numerically deterministic logits, not Echo/Sim
//! pseudo-outputs. Recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;
use std::time::Duration;

use s4::backend::{CpuSparseBackend, InferenceBackend, Value};
use s4::coordinator::{BatcherConfig, Router, RoutingPolicy, Server, ServerConfig};
use s4::runtime::Manifest;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b4", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 4, "seq": 16,
       "inputs": [{"name": "ids", "shape": [4, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

fn server(m: Manifest) -> Server {
    let backend = Arc::new(CpuSparseBackend::from_manifest(&m));
    Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            max_inflight: 64,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    )
}

fn tokens(seed: i32) -> Vec<i32> {
    (0..16).map(|t| (seed * 31 + t * 7) % 997).collect()
}

#[test]
fn bert_served_logits_are_real_and_deterministic() {
    let srv = server(manifest());
    let h = srv.handle();

    // same payload submitted twice (it may ride different artifact
    // variants/batches) → identical logits
    let t1 = h.submit("bert_tiny", vec![Value::tokens(tokens(3))]).unwrap();
    let t2 = h.submit("bert_tiny", vec![Value::tokens(tokens(3))]).unwrap();
    let t3 = h.submit("bert_tiny", vec![Value::tokens(tokens(4))]).unwrap();
    let r1 = t1.wait_timeout(Duration::from_secs(10)).unwrap();
    let r2 = t2.wait_timeout(Duration::from_secs(10)).unwrap();
    let r3 = t3.wait_timeout(Duration::from_secs(10)).unwrap();
    assert!(r1.is_ok(), "{:?}", r1.status);
    assert!(r2.is_ok() && r3.is_ok());
    assert_eq!(r1.logits().len(), 2);
    assert_eq!(r1.logits(), r2.logits(), "same input must give same logits");
    assert_ne!(r1.logits(), r3.logits(), "different input must give different logits");

    // not Echo pseudo-outputs: Echo reflects [first token, capacity, ...]
    let first_tok = tokens(3)[0] as f32;
    assert_ne!(r1.logits()[0], first_tok, "these are computed logits, not an echo");
    assert!(
        r1.logits().iter().all(|x| x.is_finite()),
        "logits finite: {:?}",
        r1.logits()
    );
    srv.shutdown();
}

#[test]
fn served_logits_match_direct_backend_execution() {
    // the coordinator's pack→run→demux must be a transparent transport
    // around the backend's own numerics
    let m = manifest();
    let backend = CpuSparseBackend::from_manifest(&m);
    let ids = tokens(11);
    // direct: hand-pack a b1 batch
    let direct = backend
        .run_batch("bert_tiny_s8_b1", &[Value::I32(ids.clone())])
        .unwrap();
    let direct_logits = direct[0].as_f32().unwrap().to_vec();

    let srv = server(m);
    let h = srv.handle();
    let t = h.submit("bert_tiny", vec![Value::tokens(ids)]).unwrap();
    let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
    assert!(r.is_ok(), "{:?}", r.status);
    assert_eq!(
        r.logits(),
        &direct_logits[..],
        "served logits must equal direct backend execution (rode {})",
        r.served_by
    );
    srv.shutdown();
}

#[test]
fn deterministic_across_server_instances() {
    // a fresh backend + server (new weights construction) reproduces the
    // exact same logits — the whole pipeline is seed-stable
    let run = || {
        let srv = server(manifest());
        let h = srv.handle();
        let t = h.submit("bert_tiny", vec![Value::tokens(tokens(7))]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        let l = r.logits().to_vec();
        srv.shutdown();
        l
    };
    assert_eq!(run(), run());
}
