//! Lifecycle tests for the v2 serving surface: deadlines, cancellation,
//! per-class accounting, and the `ServingService` conformance contract
//! (the coordinator is a transparent transport around the backend for
//! default options).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use s4::backend::{CpuSparseBackend, EchoBackend, InferenceBackend, Value};
use s4::coordinator::{
    BatcherConfig, CacheConfig, Priority, ResponseStatus, Router, RoutingPolicy, Server,
    ServerConfig, ServingService, SubmitOptions, COALESCED_LEADER_CANCELLED,
};
use s4::runtime::Manifest;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b4", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 4, "seq": 16,
       "inputs": [{"name": "ids", "shape": [4, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

fn echo_server(max_wait_ms: u64) -> Server {
    let m = manifest();
    let backend = Arc::new(EchoBackend::from_manifest(&m));
    Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            workers: 2,
            max_inflight: 64,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    )
}

fn tokens(seed: i32) -> Vec<i32> {
    (0..16).map(|t| (seed * 31 + t * 7) % 997).collect()
}

#[test]
fn deadline_expired_request_is_shed_without_executing() {
    let srv = echo_server(1);
    let h = srv.handle();
    // a deadline of zero has already elapsed when the batcher first sees
    // the request — it must be answered Expired and never executed
    let t = h
        .submit_with(
            "bert_tiny",
            vec![Value::tokens(tokens(1))],
            SubmitOptions::default().with_deadline(Duration::ZERO),
        )
        .unwrap();
    let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(r.status, ResponseStatus::Expired);
    assert!(!r.is_ok());
    assert!(r.outputs.is_empty(), "expired work must produce no outputs");
    assert!(r.logits().is_empty());
    // the shed happened before any backend execution
    let s = h.metrics_snapshot();
    assert_eq!(s.expired, 1);
    assert_eq!(s.completed, 0);
    assert_eq!(s.batches, 0, "no batch may be executed for expired-only work");
    assert_eq!(s.answered(), s.admitted);
    srv.shutdown();
}

#[test]
fn generous_deadline_still_serves() {
    let srv = echo_server(1);
    let h = srv.handle();
    let t = h
        .submit_with(
            "bert_tiny",
            vec![Value::tokens(tokens(2))],
            SubmitOptions::interactive().with_deadline(Duration::from_secs(30)),
        )
        .unwrap();
    let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
    assert!(r.is_ok(), "{:?}", r.status);
    srv.shutdown();
}

#[test]
fn cancel_racing_execution_never_double_replies() {
    // cancel at every point of the pipeline (before formation, during
    // batching, after completion): the ticket always resolves to exactly
    // one response, Ok or Cancelled
    let srv = echo_server(1);
    let h = srv.handle();
    let (mut oks, mut cancels) = (0u32, 0u32);
    for i in 0..60 {
        let t = h
            .submit("bert_tiny", vec![Value::tokens(tokens(i))])
            .unwrap();
        // vary the race window
        if i % 3 == 0 {
            std::thread::sleep(Duration::from_micros((i as u64 % 7) * 300));
        }
        t.cancel();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        match r.status {
            ResponseStatus::Ok => oks += 1,
            ResponseStatus::Cancelled => {
                assert!(r.outputs.is_empty());
                cancels += 1;
            }
            other => panic!("unexpected status {other:?}"),
        }
        // never a second reply
        std::thread::sleep(Duration::from_millis(1));
        assert!(t.try_poll().is_none(), "double reply on request {i}");
    }
    // the books balance no matter how each race resolved
    let s = h.metrics_snapshot();
    assert_eq!(s.admitted, 60);
    assert_eq!(s.completed, oks as u64);
    assert_eq!(s.cancelled, cancels as u64);
    assert_eq!(s.answered(), s.admitted, "{}", s.report());
    srv.shutdown();
}

#[test]
fn per_class_counters_track_mixed_traffic() {
    let srv = echo_server(1);
    let h = srv.handle();
    let mut tickets = Vec::new();
    for i in 0..12 {
        let opts = match i % 3 {
            0 => SubmitOptions::interactive(),
            1 => SubmitOptions::default(),
            _ => SubmitOptions::bulk(),
        };
        tickets.push(
            h.submit_with("bert_tiny", vec![Value::tokens(tokens(i))], opts)
                .unwrap(),
        );
    }
    for t in &tickets {
        assert!(t.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }
    let s = h.metrics_snapshot();
    for p in Priority::ALL {
        assert_eq!(s.class(p).admitted, 4, "{}", s.report());
        assert_eq!(s.class(p).completed, 4, "{}", s.report());
    }
    assert_eq!(s.answered(), 12);
    srv.shutdown();
}

#[test]
fn bulk_admission_budget_protects_the_queue() {
    // max_inflight 16 → default bulk cap 4: a bulk flood is clipped while
    // interactive traffic still admits
    let m = manifest();
    let backend = Arc::new(EchoBackend::from_manifest(&m));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                // max_batch above the submission count and a long fill
                // window: every submission lands while the first batch is
                // still forming, so nothing completes mid-loop and the
                // admission counts below are deterministic
                max_batch: 8,
                max_wait: Duration::from_millis(200),
            },
            workers: 1,
            max_inflight: 16,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let mut bulk_ok = 0;
    let mut bulk_rejected = 0;
    let mut keep = Vec::new();
    for i in 0..10 {
        match h.submit_with(
            "bert_tiny",
            vec![Value::tokens(tokens(i))],
            SubmitOptions::bulk(),
        ) {
            Ok(t) => {
                bulk_ok += 1;
                keep.push(t);
            }
            Err(d) => {
                assert!(matches!(
                    d,
                    s4::coordinator::AdmissionDecision::RejectQueueFull(Priority::Bulk)
                ));
                bulk_rejected += 1;
            }
        }
    }
    assert_eq!(bulk_ok, 4, "bulk budget is max_inflight/4");
    assert_eq!(bulk_rejected, 6);
    // interactive still has headroom
    let t = h
        .submit_with(
            "bert_tiny",
            vec![Value::tokens(tokens(99))],
            SubmitOptions::interactive(),
        )
        .unwrap();
    keep.push(t);
    for t in &keep {
        assert!(t.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }
    srv.shutdown();
}

#[test]
fn serving_service_matches_direct_backend_execution() {
    // conformance: submitting through the `ServingService` trait object
    // with default options yields bitwise the logits of direct backend
    // execution — the coordinator adds QoS, not numerics
    let m = manifest();
    let backend = CpuSparseBackend::from_manifest(&m);
    let ids = tokens(11);
    let direct = backend
        .run_batch("bert_tiny_s8_b1", &[Value::tokens(ids.clone())])
        .unwrap();
    let direct_logits = direct[0].as_f32().unwrap().to_vec();

    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            max_inflight: 64,
            ..Default::default()
        },
        manifest(),
        Router::new(RoutingPolicy::MaxSparsity),
        Arc::new(CpuSparseBackend::from_manifest(&manifest())),
    );
    let handle = srv.handle();
    let svc: &dyn ServingService = &handle;
    let t = svc.submit("bert_tiny", vec![Value::tokens(ids)]).unwrap();
    assert_eq!(t.priority(), Priority::Standard, "default options are Standard");
    let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
    assert!(r.is_ok(), "{:?}", r.status);
    assert_eq!(
        r.logits(),
        &direct_logits[..],
        "served logits must equal direct backend execution (rode {})",
        r.served_by
    );
    let snap = svc.metrics_snapshot();
    assert_eq!(snap.completed, 1);
    srv.shutdown();
}

#[test]
fn shed_requests_release_admission_capacity() {
    // a cancelled backlog must not clog max_inflight: after shedding,
    // new submissions admit again
    let m = manifest();
    let backend = Arc::new(EchoBackend::from_manifest(&m));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 4,
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let tickets: Vec<_> = (0..4)
        .map(|i| h.submit("bert_tiny", vec![Value::tokens(tokens(i))]).unwrap())
        .collect();
    for t in &tickets {
        t.cancel();
    }
    for t in &tickets {
        // each resolves exactly once (served or cancelled)
        let _ = t.wait_timeout(Duration::from_secs(5)).unwrap();
    }
    // capacity is back: a fresh submit admits (the slot release runs
    // just after the response send, so allow a bounded settle window)
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    let t = loop {
        match h.submit("bert_tiny", vec![Value::tokens(tokens(9))]) {
            Ok(t) => break t,
            Err(_) => {
                assert!(std::time::Instant::now() < deadline, "capacity never released");
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    };
    assert!(t.wait_timeout(Duration::from_secs(5)).unwrap().is_ok());
    let s = h.metrics_snapshot();
    assert_eq!(s.answered(), s.admitted, "{}", s.report());
    assert_eq!(
        h.metrics.admitted.load(Ordering::Relaxed),
        s.admitted,
        "snapshot mirrors raw counters"
    );
    srv.shutdown();
}

/// Echo server with the response cache enabled. `max_wait_ms` doubles as
/// the coalescing window: with `max_batch` above the submission count,
/// a leader sits in the batcher stash for up to `max_wait_ms` while
/// identical followers attach to it.
fn cached_server(max_batch: usize, max_wait_ms: u64, cache: CacheConfig) -> Server {
    let m = manifest();
    let backend = Arc::new(EchoBackend::from_manifest(&m));
    Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            workers: 1,
            max_inflight: 64,
            cache: Some(cache),
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    )
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn cache_hit_returns_bitwise_identical_logits() {
    let srv = cached_server(1, 1, CacheConfig::default());
    let h = srv.handle();
    let first = h
        .submit("bert_tiny", vec![Value::tokens(tokens(5))])
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(first.is_ok(), "{:?}", first.status);
    let second = h
        .submit("bert_tiny", vec![Value::tokens(tokens(5))])
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(second.is_ok(), "{:?}", second.status);
    assert!(
        second.served_by.starts_with("cache:"),
        "hit must be marked, got {:?}",
        second.served_by
    );
    assert_eq!(
        bits(first.logits()),
        bits(second.logits()),
        "cached logits must be bitwise-identical to the miss that populated them"
    );
    assert_ne!(first.id, second.id, "each caller keeps its own request id");
    let s = h.metrics_snapshot();
    assert_eq!((s.cache_hits, s.cache_misses), (1, 1), "{}", s.report());
    assert_eq!(s.admitted, 1, "the hit never touched admission");
    assert_eq!(s.answered(), s.admitted, "{}", s.report());
    assert_eq!(s.served(), 2, "one executed + one hit");
    assert_eq!(s.cache_size, 1);
    // a different payload is a miss, not a collision
    let other = h
        .submit("bert_tiny", vec![Value::tokens(tokens(6))])
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(!other.served_by.starts_with("cache:"));
    srv.shutdown();
}

#[test]
fn coalesced_followers_share_one_execution() {
    // max_batch 8 with a 200 ms fill window: the leader sits in the
    // batcher stash while identical followers attach through the cache
    let srv = cached_server(8, 200, CacheConfig::default());
    let h = srv.handle();
    let leader = h.submit("bert_tiny", vec![Value::tokens(tokens(7))]).unwrap();
    let followers: Vec<_> = (0..3)
        .map(|_| h.submit("bert_tiny", vec![Value::tokens(tokens(7))]).unwrap())
        .collect();
    let lead_resp = leader.wait_timeout(Duration::from_secs(5)).unwrap();
    assert!(lead_resp.is_ok(), "{:?}", lead_resp.status);
    for f in &followers {
        let r = f.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        assert_eq!(r.id, f.id(), "follower keeps its own id");
        assert_eq!(
            bits(r.logits()),
            bits(lead_resp.logits()),
            "every coalesced waiter gets the leader's bits"
        );
    }
    let s = h.metrics_snapshot();
    assert_eq!(s.coalesced, 3, "{}", s.report());
    assert_eq!(s.admitted, 1, "exactly one backend execution admitted");
    assert_eq!(s.completed, 1, "{}", s.report());
    assert_eq!(s.answered(), s.admitted, "{}", s.report());
    assert_eq!(s.served(), 4);
    assert_eq!(h.inflight(), 0);
    srv.shutdown();
}

#[test]
fn follower_cancel_does_not_disturb_the_leader() {
    let srv = cached_server(8, 200, CacheConfig::default());
    let h = srv.handle();
    let leader = h.submit("bert_tiny", vec![Value::tokens(tokens(8))]).unwrap();
    let follower = h.submit("bert_tiny", vec![Value::tokens(tokens(8))]).unwrap();
    // cancel the follower while the leader is still stashed: a coalesced
    // cancel is a no-op — it must not propagate to the leader's flag
    follower.cancel();
    assert!(follower.is_cancelled());
    let lead_resp = leader.wait_timeout(Duration::from_secs(5)).unwrap();
    assert!(
        lead_resp.is_ok(),
        "follower cancel must not shed the leader: {:?}",
        lead_resp.status
    );
    // the follower still receives the leader's outcome (work that
    // completes anyway answers Ok — the cooperative-cancel contract)
    let f_resp = follower.wait_timeout(Duration::from_secs(5)).unwrap();
    assert!(f_resp.is_ok(), "{:?}", f_resp.status);
    let s = h.metrics_snapshot();
    assert_eq!(s.cancelled, 0, "nothing was shed: {}", s.report());
    assert_eq!((s.admitted, s.coalesced), (1, 1));
    srv.shutdown();
}

#[test]
fn leader_cancel_settles_followers_retryable_not_cancelled() {
    // the mirror of follower_cancel_does_not_disturb_the_leader: when the
    // LEADER's client cancels, a coalesced follower — who never cancelled
    // — must not receive ResponseStatus::Cancelled; it gets the distinct
    // retryable error and a clean resubmission executes fresh
    let srv = cached_server(8, 200, CacheConfig::default());
    let h = srv.handle();
    let leader = h.submit("bert_tiny", vec![Value::tokens(tokens(11))]).unwrap();
    let follower = h.submit("bert_tiny", vec![Value::tokens(tokens(11))]).unwrap();
    leader.cancel();
    let lead_resp = leader.wait_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(
        lead_resp.status,
        ResponseStatus::Cancelled,
        "the leader's own cancel is its own outcome"
    );
    let f_resp = follower.wait_timeout(Duration::from_secs(5)).unwrap();
    assert_ne!(
        f_resp.status,
        ResponseStatus::Cancelled,
        "a follower must never inherit someone else's cancel"
    );
    assert_eq!(f_resp.error_message(), Some(COALESCED_LEADER_CANCELLED));
    assert_eq!(f_resp.id, follower.id());
    // the shed was never cached: a retry is a fresh miss that executes
    let retry = h
        .submit("bert_tiny", vec![Value::tokens(tokens(11))])
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(retry.is_ok(), "retry must execute fresh: {:?}", retry.status);
    assert!(!retry.served_by.starts_with("cache:"));
    let s = h.metrics_snapshot();
    assert_eq!(s.cancelled, 1, "exactly the leader was shed: {}", s.report());
    assert_eq!(s.coalesced, 1, "{}", s.report());
    assert_eq!(s.answered(), s.admitted, "{}", s.report());
    srv.shutdown();
}

#[test]
fn ttl_zero_always_re_executes() {
    let srv = cached_server(1, 1, CacheConfig { ttl: Duration::ZERO, ..CacheConfig::default() });
    let h = srv.handle();
    for _ in 0..2 {
        let r = h
            .submit("bert_tiny", vec![Value::tokens(tokens(9))])
            .unwrap()
            .wait_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        assert!(!r.served_by.starts_with("cache:"), "ttl=0 disables reuse");
    }
    let s = h.metrics_snapshot();
    assert_eq!(s.admitted, 2, "both executed: {}", s.report());
    assert_eq!(s.cache_hits, 0);
    assert_eq!(s.cache_misses, 2);
    srv.shutdown();
}

#[test]
fn cache_never_replays_an_error_response() {
    // backend errors on its first call only: the error must answer the
    // first caller but never be served from the cache to the second
    let m = manifest();
    let backend = Arc::new(s4::fault::FaultingBackend::new(
        Arc::new(EchoBackend::from_manifest(&m)) as Arc<dyn InferenceBackend>,
        s4::fault::FaultPlan::new().with_error_burst(0, 1),
    ));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 8,
            cache: Some(CacheConfig::default()),
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let first = h
        .submit("bert_tiny", vec![Value::tokens(tokens(3))])
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(!first.is_ok(), "fault must surface to the first caller");
    let second = h
        .submit("bert_tiny", vec![Value::tokens(tokens(3))])
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap();
    assert!(second.is_ok(), "the error was not cached: {:?}", second.status);
    assert!(!second.served_by.starts_with("cache:"), "re-executed, not replayed");
    let s = h.metrics_snapshot();
    assert_eq!(s.cache_hits, 0, "{}", s.report());
    assert_eq!(s.admitted, 2);
    assert_eq!(s.answered(), s.admitted, "{}", s.report());
    srv.shutdown();
}
