//! Cross-module integration tests: the paper's claims as assertions over
//! the composed system (graph IR + simulator + baseline + coordinator).

use std::sync::Arc;
use std::time::Duration;

use s4::arch::AntoumConfig;
use s4::backend::Value;
use s4::coordinator::{
    BatcherConfig, Router, RoutingPolicy, Server, ServerConfig, SimBackend,
};
use s4::graph::models;
use s4::sim::report::{dominates, Fig3Point};
use s4::sim::{simulate, simulate_event, Parallelism, Target};
use s4::sparse::tensor::DType;

fn s4cfg() -> AntoumConfig {
    AntoumConfig::s4()
}

// ------------------------------ Fig. 2 ------------------------------------

#[test]
fn fig2_shape_resnet_nearly_linear_bert_sublinear() {
    let resnet = models::resnet50(16, 224);
    let bert = models::bert(models::BERT_BASE, 16, 128);
    let base_r = simulate(&resnet, Target::antoum(&s4cfg(), 1)).throughput;
    let base_b = simulate(&bert, Target::antoum(&s4cfg(), 1)).throughput;
    let mut prev_r = 0.0;
    let mut prev_b = 0.0;
    for &s in &[2usize, 4, 8, 16, 32] {
        let sp_r = simulate(&resnet, Target::antoum(&s4cfg(), s)).throughput / base_r;
        let sp_b = simulate(&bert, Target::antoum(&s4cfg(), s)).throughput / base_b;
        // both monotone; resnet closer to ideal than bert at every s
        assert!(sp_r > prev_r && sp_b > prev_b, "monotonicity at s={s}");
        assert!(sp_r > sp_b, "resnet {sp_r:.1} vs bert {sp_b:.1} at s={s}");
        // resnet "almost linear" (≥70% of ideal)
        assert!(sp_r >= 0.7 * s as f64, "resnet s={s}: {sp_r:.1}");
        prev_r = sp_r;
        prev_b = sp_b;
    }
    assert!(prev_b < 24.0, "bert at 32x must bend: {prev_b:.1}");
}

#[test]
fn fig2_s4_beats_t4_at_high_sparsity() {
    // the paper's headline: several-times speedup over T4 with sparsity
    for (g, factor) in [
        (models::resnet50(16, 224), 16usize),
        (models::bert(models::BERT_BASE, 16, 128), 16),
    ] {
        let t4 = simulate(&g, Target::t4()).throughput;
        let s4_dense = simulate(&g, Target::antoum(&s4cfg(), 1)).throughput;
        let s4_sparse = simulate(&g, Target::antoum(&s4cfg(), factor)).throughput;
        assert!(
            s4_dense < t4,
            "{}: dense S4 ({s4_dense:.0}) should NOT beat T4 ({t4:.0}) — \
             sparsity is the whole point",
            g.name
        );
        assert!(
            s4_sparse > 1.5 * t4,
            "{}: sparse-{factor} S4 {s4_sparse:.0} vs T4 {t4:.0}",
            g.name
        );
    }
}

// ------------------------------ Fig. 3 ------------------------------------

#[test]
fn fig3_larger_sparse_dominates_smaller_dense() {
    // throughput side of the Fig. 3 insight, with the published top-1
    // accuracies as the accuracy side (the paper's premise: larger models
    // keep higher accuracy under sparsity).
    let r152_s4 = simulate(&models::resnet152(16, 224), Target::antoum(&s4cfg(), 8));
    let r50_t4 = simulate(&models::resnet50(16, 224), Target::t4());
    let a = Fig3Point {
        model: "resnet152".into(),
        platform: "s4".into(),
        sparsity: 8,
        accuracy: 0.782,
        throughput: r152_s4.throughput,
    };
    let b = Fig3Point {
        model: "resnet50".into(),
        platform: "t4".into(),
        sparsity: 1,
        accuracy: 0.761,
        throughput: r50_t4.throughput,
    };
    assert!(
        dominates(&a, &b),
        "sparse-large {:.0}/s vs dense-small {:.0}/s",
        a.throughput,
        b.throughput
    );
}

// --------------------------- event vs analytic -----------------------------

#[test]
fn event_and_analytic_agree_across_models_and_sparsities() {
    for g in [
        models::resnet50(8, 224),
        models::bert(models::BERT_BASE, 8, 128),
        models::bert(models::BERT_TINY, 8, 128),
    ] {
        for &s in &[1usize, 8, 32] {
            let a = simulate(&g, Target::antoum(&s4cfg(), s));
            let e = simulate_event(&g, &s4cfg(), s, DType::Int8, Parallelism::DataParallel);
            let ratio = e.latency_ms / a.latency_ms;
            assert!(
                (0.4..2.5).contains(&ratio),
                "{} s={s}: event {:.3}ms vs analytic {:.3}ms",
                g.name,
                e.latency_ms,
                a.latency_ms
            );
        }
    }
}

// ------------------------- python/rust consistency -------------------------

#[test]
fn bert_flops_match_python_accounting() {
    // python compile/model.py::bert_flops(BERT_BASE, 1, 128, 1) computes the
    // same decomposition; this pins the two within 15% so neither drifts.
    let g = models::bert(models::BERT_BASE, 1, 128);
    let rust_total = g.flops_dense();
    let (h, f, l, heads, seq) = (768.0f64, 3072.0, 12.0, 12.0, 128.0);
    let m = seq;
    let proj = 2.0 * m * h * h * 4.0;
    let ffn = 2.0 * m * h * f * 2.0;
    let attn = 2.0 * heads * seq * seq * (h / heads) * 2.0;
    let other = m * h * 20.0;
    let py_total = l * (proj + ffn + attn + other);
    let ratio = rust_total / py_total;
    assert!((0.85..1.15).contains(&ratio), "rust/python FLOPs ratio {ratio}");
}

// ------------------------------ serving -----------------------------------

#[test]
fn serving_stack_under_simulated_load() {
    use s4::runtime::Manifest;
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s1_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 1, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;
    let manifest = Manifest::parse(std::path::Path::new("/tmp"), text).unwrap();
    // time_scale tiny so the test is fast but ordering still holds
    let backend = Arc::new(SimBackend::from_manifest(&manifest, 0.01));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            workers: 2,
            max_inflight: 128,
            ..Default::default()
        },
        manifest,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let tickets: Vec<_> = (0..48)
        .filter_map(|i| h.submit("bert_tiny", vec![Value::tokens(vec![i as i32; 32])]).ok())
        .collect();
    assert!(tickets.len() >= 40, "most requests admitted");
    let mut served_by_sparse = 0;
    for t in tickets {
        let r = t.wait_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        if &*r.served_by == "bert_tiny_s8_b8" {
            served_by_sparse += 1;
        }
    }
    assert!(served_by_sparse > 0, "MaxSparsity policy must route to s=8");
    assert!(h.metrics.mean_batch_fill() > 1.0, "{}", h.metrics.report());
    srv.shutdown();
}

#[test]
fn dense_policy_routes_dense() {
    use s4::runtime::Manifest;
    let text = r#"{"artifacts": [
      {"name": "m_s1_b1", "file": "x", "family": "bert", "model": "bert_tiny",
       "sparsity": 1, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "m_s32_b1", "file": "y", "family": "bert", "model": "bert_tiny",
       "sparsity": 32, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]}
    ]}"#;
    let manifest = Manifest::parse(std::path::Path::new("/tmp"), text).unwrap();
    let backend = Arc::new(SimBackend::from_manifest(&manifest, 0.001));
    let srv = Server::start(
        ServerConfig::default(),
        manifest,
        Router::new(RoutingPolicy::Dense),
        backend,
    );
    let h = srv.handle();
    let t = h.submit("bert_tiny", vec![Value::tokens(vec![1; 16])]).unwrap();
    let r = t.wait_timeout(Duration::from_secs(30)).unwrap();
    assert!(r.is_ok());
    assert_eq!(&*r.served_by, "m_s1_b1");
    srv.shutdown();
}

#[test]
fn tokens_and_images_serve_through_one_inference_backend() {
    // the acceptance claim of the unified API: a BERT-style token request
    // and a ResNet-style image request served by the same coordinator over
    // the same `InferenceBackend` instance
    use s4::runtime::Manifest;
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b4", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 4, "seq": 16,
       "inputs": [{"name": "ids", "shape": [4, 16], "dtype": "s32"}],
       "outputs": [{"shape": [4, 2], "dtype": "f32"}]},
      {"name": "resnet50_s8_b4", "file": "y", "family": "resnet",
       "model": "resnet50", "sparsity": 8, "batch": 4, "seq": 0,
       "inputs": [{"name": "images", "shape": [4, 192], "dtype": "f32"}],
       "outputs": [{"shape": [4, 10], "dtype": "f32"}]}
    ]}"#;
    let manifest = Manifest::parse(std::path::Path::new("/tmp"), text).unwrap();
    let backend = Arc::new(SimBackend::from_manifest(&manifest, 0.001));
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            max_inflight: 64,
            ..Default::default()
        },
        manifest,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();
    let t_txt = h.submit("bert_tiny", vec![Value::tokens(vec![7; 16])]).unwrap();
    let t_img = h
        .submit("resnet50", vec![Value::F32(vec![0.5; 192])])
        .unwrap();
    let txt = t_txt.wait_timeout(Duration::from_secs(30)).unwrap();
    let img = t_img.wait_timeout(Duration::from_secs(30)).unwrap();
    assert!(txt.is_ok(), "{:?}", txt.status);
    assert!(img.is_ok(), "{:?}", img.status);
    assert_eq!(&*txt.served_by, "bert_tiny_s8_b4");
    assert_eq!(&*img.served_by, "resnet50_s8_b4");
    assert_eq!(txt.logits().len(), 2);
    assert_eq!(img.logits().len(), 10);
    srv.shutdown();
}

// ----------------------------- energy/TCO ---------------------------------

#[test]
fn samples_per_joule_improves_with_sparsity() {
    let g = models::resnet50(16, 224);
    let e1 = simulate(&g, Target::antoum(&s4cfg(), 1)).samples_per_joule();
    let e16 = simulate(&g, Target::antoum(&s4cfg(), 16)).samples_per_joule();
    assert!(e16 > 3.0 * e1, "energy efficiency must scale: {e1} → {e16}");
    let t4 = simulate(&g, Target::t4()).samples_per_joule();
    assert!(e16 > t4, "S4 sparse {e16} vs T4 {t4} samples/J");
}
