//! End-to-end runtime tests (feature `pjrt`): load real AOT artifacts
//! (built by `make artifacts`), compile them on the PJRT CPU client,
//! execute, and compare against the golden outputs recorded by the Python
//! side.
//!
//! This is the proof that all three layers compose: the Pallas sparse
//! kernel (L1) lowered inside the JAX model (L2) executes under the rust
//! runtime (L3) with matching numerics.
//!
//! The whole file is compiled only with `--features pjrt` (the default
//! build has no PJRT); within that, tests are skipped (not failed) when
//! artifacts are absent so `cargo test` works pre-`make artifacts`.
#![cfg(feature = "pjrt")]

use s4::runtime::{default_artifact_dir, Executor, Manifest, Value};

fn manifest_or_skip() -> Option<Manifest> {
    let dir = default_artifact_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime_e2e: {e}");
            None
        }
    }
}

#[test]
fn load_and_execute_bert_tiny_matches_golden() {
    let Some(m) = manifest_or_skip() else { return };
    let mut ex = Executor::cpu().expect("pjrt cpu client");
    let name = "bert_tiny_s8_b1";
    let model = ex.load(&m, name).expect("compile artifact");
    let meta = m.get(name).unwrap().clone();
    let (input, expect) = m.golden(&meta).expect("golden");
    let tokens: Vec<i32> = input.iter().map(|&x| x as i32).collect();
    let out = model.run(&[Value::I32(tokens)]).expect("execute");
    assert_eq!(out.len(), 1);
    let logits = out[0].as_f32().expect("f32 output");
    assert_eq!(logits.len(), expect.len());
    for (i, (&got, &want)) in logits.iter().zip(&expect).enumerate() {
        assert!(
            (got as f64 - want).abs() < 1e-3 * want.abs().max(1.0),
            "logit {i}: rust={got} python={want}"
        );
    }
}

#[test]
fn all_artifacts_compile_and_match_goldens() {
    let Some(m) = manifest_or_skip() else { return };
    let mut ex = Executor::cpu().unwrap();
    for a in m.artifacts.clone() {
        ex.load(&m, &a.name).unwrap_or_else(|e| panic!("{}: {e}", a.name));
        let model = ex.loaded(&a.name).unwrap();
        let (input, expect) = m.golden(&a).unwrap();
        let val = match a.inputs[0].dtype.as_str() {
            "s32" => Value::I32(input.iter().map(|&x| x as i32).collect()),
            "f32" => Value::F32(input.iter().map(|&x| x as f32).collect()),
            other => panic!("dtype {other}"),
        };
        let out = model.run(&[val]).unwrap_or_else(|e| panic!("{}: {e}", a.name));
        let logits = out[0].as_f32().expect("f32 output");
        let max_rel = logits
            .iter()
            .zip(&expect)
            .map(|(&g, &w)| (g as f64 - w).abs() / w.abs().max(1.0))
            .fold(0.0f64, f64::max);
        assert!(max_rel < 1e-3, "{}: max rel err {max_rel}", a.name);
        println!("{}: OK (max rel err {max_rel:.2e})", a.name);
    }
}

#[test]
fn executor_caches_compilations() {
    let Some(m) = manifest_or_skip() else { return };
    let mut ex = Executor::cpu().unwrap();
    let name = "bert_tiny_s32_b1";
    ex.load(&m, name).unwrap();
    assert_eq!(ex.loaded_count(), 1);
    ex.load(&m, name).unwrap(); // cache hit
    assert_eq!(ex.loaded_count(), 1);
    assert!(ex.loaded(name).is_some());
    assert!(ex.loaded("nope").is_none());
}

#[test]
fn wrong_input_shape_rejected() {
    let Some(m) = manifest_or_skip() else { return };
    let mut ex = Executor::cpu().unwrap();
    let model = ex.load(&m, "bert_tiny_s8_b1").unwrap();
    let err = model.run(&[Value::I32(vec![1, 2, 3])]).unwrap_err();
    assert!(err.to_string().contains("elems"), "{err}");
    let err2 = model.run(&[]).unwrap_err();
    assert!(err2.to_string().contains("inputs"), "{err2}");
}

#[test]
fn batch8_variant_runs_eight_samples() {
    let Some(m) = manifest_or_skip() else { return };
    let mut ex = Executor::cpu().unwrap();
    let name = "bert_tiny_s8_b8";
    let Some(meta) = m.get(name).cloned() else {
        eprintln!("SKIP: {name} not built");
        return;
    };
    let elems = meta.inputs[0].elems();
    let model = ex.load(&m, name).unwrap();
    let out = model.run(&[Value::I32(vec![7; elems])]).unwrap();
    let logits = out[0].as_f32().expect("f32 output");
    assert_eq!(logits.len(), meta.outputs[0].elems());
    // identical rows in → identical logits out (batch independence)
    let c = meta.outputs[0].shape[1];
    for b in 1..meta.outputs[0].shape[0] {
        for k in 0..c {
            assert!((logits[b * c + k] - logits[k]).abs() < 1e-4);
        }
    }
}
