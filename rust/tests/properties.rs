//! Property-based tests (in-repo `util::prop` runner) over the coordinator
//! and substrate invariants the brief calls out: routing conservation,
//! batching non-loss, priority-scheduling order, sparse-format structure,
//! event-sim sanity.

use s4::coordinator::{Router, RoutingPolicy};
use s4::prop_assert;
use s4::runtime::Manifest;
use s4::sparse::format::{BlockBalanced, BLOCK};
use s4::sparse::matmul::{dense_mm, spmm, Act};
use s4::sparse::pack::{qspmm_tiled, spmm_tiled};
use s4::sparse::quant::{qspmm, quant_drift_bound};
use s4::sparse::tensor::Dense2;
use s4::util::prop::{check, Gen};

fn manifest_with_batches(batches: &[usize], sparsity: usize) -> Manifest {
    let arts: Vec<String> = batches
        .iter()
        .map(|b| {
            format!(
                r#"{{"name": "m_s{s}_b{b}", "file": "f", "family": "bert",
                     "model": "m", "sparsity": {s}, "batch": {b},
                     "inputs": [], "outputs": []}}"#,
                s = sparsity,
                b = b
            )
        })
        .collect();
    Manifest::parse(
        std::path::Path::new("/tmp"),
        &format!(r#"{{"artifacts": [{}]}}"#, arts.join(",")),
    )
    .unwrap()
}

#[test]
fn prop_router_plan_conserves_requests() {
    check("router conservation", 200, |g: &mut Gen| {
        // random capacity set (1 plus up to 3 others), random batch size
        let mut caps = vec![1usize];
        for _ in 0..g.usize_in(0, 3) {
            caps.push(*g.pick(&[2usize, 4, 8, 16, 32]));
        }
        caps.sort_unstable();
        caps.dedup();
        let m = manifest_with_batches(&caps, 8);
        let n = g.usize_in(1, 100);
        let r = Router::new(RoutingPolicy::Fixed(8));
        let plan = r.plan(&m, "m", n).map_err(|e| e.to_string())?;
        let total: usize = plan.iter().map(|p| p.fill).sum();
        prop_assert!(total == n, "plan covers {total} of {n}: {plan:?}");
        for p in &plan {
            prop_assert!(p.fill <= p.batch_capacity, "overfill {p:?}");
            prop_assert!(p.fill > 0, "empty placement {p:?}");
        }
        // padding never exceeds one placement's worth
        let padded: usize = plan.iter().map(|p| p.batch_capacity - p.fill).sum();
        let max_cap = *caps.last().unwrap();
        prop_assert!(padded < max_cap, "padding {padded} ≥ largest cap {max_cap}");
        Ok(())
    });
}

#[test]
fn prop_batch_formation_never_seeds_past_a_stashed_interactive() {
    // the QoS scheduling invariant: with the whole backlog visible, a
    // batch is never seeded from a lower-urgency class while a
    // higher-urgency request (for ANY model) is still stashed — and no
    // request is ever lost across batches
    use s4::backend::Value;
    use s4::coordinator::{
        BatcherConfig, DynamicBatcher, Priority, ReplySlot, Request, RequestId,
    };
    use std::sync::atomic::AtomicBool;
    use std::sync::{mpsc, Arc};
    use std::time::{Duration, Instant};

    check("priority batch seeding", 80, |g: &mut Gen| {
        let models = ["a", "b", "c"];
        let n = g.usize_in(1, 30);
        let max_batch = g.usize_in(1, 6);
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..n {
            let (rtx, rrx) = mpsc::channel();
            let r = Request {
                id: RequestId(i as u64),
                model: Arc::from(*g.pick(&models)),
                inputs: vec![Value::tokens(vec![0; 4])],
                submitted: Instant::now(),
                priority: *g.pick(&Priority::ALL),
                deadline: None,
                cancelled: Arc::new(AtomicBool::new(false)),
                client_tag: None,
                reply: ReplySlot::new(rtx),
            };
            tx.send(r).map_err(|e| e.to_string())?;
            replies.push(rrx);
        }
        drop(tx); // all requests visible up front; no mid-fill arrivals
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch, max_wait: Duration::ZERO },
            rx,
        );
        let mut total = 0usize;
        while let Some(batch) = b.next_batch() {
            total += batch.len();
            let seed = batch.requests[0].priority;
            let depth = b.stash_depth_by_class();
            for p in Priority::ALL {
                if p < seed {
                    prop_assert!(
                        depth[p.idx()] == 0,
                        "seeded {seed:?} while {} {p:?} request(s) stashed \
                         (n={n} max_batch={max_batch})",
                        depth[p.idx()]
                    );
                }
            }
            for r in &batch.requests {
                prop_assert!(r.model == batch.model, "mixed-model batch");
            }
        }
        prop_assert!(total == n, "lost requests: batched {total} of {n}");
        Ok(())
    });
}

#[test]
fn prop_coalesced_waiters_get_identical_outputs_exactly_one_execution() {
    // the single-flight contract of the response cache: N concurrent
    // identical submissions — one leader plus N-1 coalesced followers,
    // some of whom cancel while the flight is pending — produce exactly
    // one backend execution, and every waiter (cancelled or not; a
    // coalesced cancel is a no-op once attached) receives an Ok response
    // whose logits are bitwise identical to the leader's, stamped with
    // its own request id. The ticket ledger stays exact: answered() ==
    // admitted and served() == answered() + cache_hits + coalesced == N.
    use s4::backend::{EchoBackend, Value};
    use s4::coordinator::{
        BatcherConfig, CacheConfig, Router, RoutingPolicy, Server, ServerConfig, Ticket,
    };
    use std::sync::Arc;
    use std::time::Duration;

    let manifest = Manifest::parse(
        std::path::Path::new("/tmp"),
        r#"{"artifacts": [
          {"name": "m_s8_b1", "file": "x", "family": "bert",
           "model": "m", "sparsity": 8, "batch": 1, "seq": 32,
           "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
           "outputs": [{"shape": [1, 2], "dtype": "f32"}]}
        ]}"#,
    )
    .unwrap();
    check("cache single-flight coalescing", 20, |g: &mut Gen| {
        let backend = Arc::new(EchoBackend::from_manifest(&manifest));
        let srv = Server::start(
            ServerConfig {
                // batch window far above the submit burst, so every
                // follower attaches while the leader is still stashed
                batcher: BatcherConfig {
                    max_batch: 16,
                    max_wait: Duration::from_millis(100),
                },
                workers: 1,
                max_inflight: 64,
                cache: Some(CacheConfig::default()),
                ..Default::default()
            },
            manifest.clone(),
            Router::new(RoutingPolicy::MaxSparsity),
            backend,
        );
        let h = srv.handle();

        let n = g.usize_in(2, 8);
        let payload = vec![Value::tokens(vec![g.usize_in(0, 996) as i32; 32])];
        let mut tickets: Vec<Ticket> = Vec::with_capacity(n);
        for _ in 0..n {
            tickets.push(
                h.submit("m", payload.clone())
                    .map_err(|d| format!("rejected: {d:?}"))?,
            );
        }
        // random follower cancels mid-flight (never the leader slot 0)
        for t in tickets.iter().skip(1) {
            if g.bool() {
                t.cancel();
            }
        }

        let mut bits: Vec<Vec<u32>> = Vec::with_capacity(n);
        let mut ids = std::collections::HashSet::new();
        for t in &tickets {
            let r = t
                .wait_timeout(Duration::from_secs(30))
                .map_err(|e| e.to_string())?;
            prop_assert!(r.is_ok(), "waiter failed: {:?} (n={n})", r.status);
            prop_assert!(ids.insert(r.id), "duplicate response id {:?}", r.id);
            let logits = r.logits();
            prop_assert!(!logits.is_empty(), "waiter got empty logits (n={n})");
            bits.push(logits.iter().map(|x| x.to_bits()).collect());
        }
        for b in &bits[1..] {
            prop_assert!(b == &bits[0], "coalesced outputs diverge (n={n})");
        }

        let s = h.metrics_snapshot();
        let inflight = h.inflight();
        srv.shutdown();
        prop_assert!(s.admitted == 1, "admitted {} != 1 (n={n})", s.admitted);
        prop_assert!(s.completed == 1, "completed {} != 1 (n={n})", s.completed);
        prop_assert!(
            s.coalesced == (n - 1) as u64,
            "coalesced {} != {} (n={n})",
            s.coalesced,
            n - 1
        );
        prop_assert!(
            s.answered() == s.admitted,
            "ticket ledger broken: {}",
            s.report()
        );
        prop_assert!(s.served() == n as u64, "served {} != {n}", s.served());
        prop_assert!(inflight == 0, "leaked admission slots: {inflight}");
        Ok(())
    });
}

#[test]
fn prop_block_balanced_structure_holds() {
    check("block-balanced invariants", 100, |g: &mut Gen| {
        let kb = g.usize_in(1, 4);
        let n = g.usize_in(1, 24);
        let s = *g.pick(&[1usize, 2, 4, 8, 16, 32]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let w = Dense2::randn(kb * BLOCK, n, seed);
        let bb = BlockBalanced::from_dense(&w, s).map_err(|e| e.to_string())?;
        bb.validate().map_err(|e| e.to_string())?;
        let d = bb.to_dense();
        // per (block, col) non-zero budget
        let keep = BLOCK / s;
        for blk in 0..kb {
            for c in 0..n {
                let nz = (0..BLOCK)
                    .filter(|&r| d.at(blk * BLOCK + r, c) != 0.0)
                    .count();
                prop_assert!(nz <= keep, "blk {blk} col {c}: {nz} > {keep}");
            }
        }
        // kept values preserved exactly
        for r in 0..d.rows {
            for c in 0..n {
                let v = d.at(r, c);
                prop_assert!(
                    v == 0.0 || v == w.at(r, c),
                    "mutated value at ({r},{c})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_dense_reference() {
    check("spmm numerics", 60, |g: &mut Gen| {
        let m = g.usize_in(1, 12);
        let kb = g.usize_in(1, 3);
        let n = g.usize_in(1, 12);
        let s = *g.pick(&[1usize, 4, 16]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let x = Dense2::randn(m, kb * BLOCK, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(kb * BLOCK, n, seed + 1), s)
            .map_err(|e| e.to_string())?;
        let act = *g.pick(&[Act::None, Act::Relu, Act::Gelu]);
        let y = spmm(&x, &w, None, act);
        let yd = dense_mm(&x, &w.to_dense(), None, act);
        let diff = y.max_abs_diff(&yd);
        prop_assert!(diff < 1e-3, "diff {diff} (m={m} k={} n={n} s={s})", kb * BLOCK);
        Ok(())
    });
}

#[test]
fn prop_tiled_spmm_matches_serial_and_dense() {
    // the differential contract of the parallel engine: for random
    // shapes, every supported sparsity, any thread count and tile width
    // (including widths that split the output mid-tile), the tiled
    // kernel is bit-identical to the serial reference and within fp
    // tolerance of the dense reference
    check("tiled spmm differential", 60, |g: &mut Gen| {
        let m = g.usize_in(1, 24);
        let kb = g.usize_in(1, 3);
        let n = g.usize_in(1, 40);
        let s = *g.pick(&[1usize, 2, 4, 8, 16, 32]);
        let threads = g.usize_in(1, 4);
        let n_tile = *g.pick(&[3usize, 8, 16, 128]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let x = Dense2::randn(m, kb * BLOCK, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(kb * BLOCK, n, seed + 1), s)
            .map_err(|e| e.to_string())?;
        let bias: Option<Vec<f32>> = if g.bool() {
            Some((0..n).map(|i| (i as f32).sin()).collect())
        } else {
            None
        };
        let act = *g.pick(&[Act::None, Act::Relu, Act::Gelu]);
        let serial = spmm(&x, &w, bias.as_deref(), act);
        let tiled = spmm_tiled(&x, &w.pack_tiled(n_tile), bias.as_deref(), act, threads);
        prop_assert!(
            serial.data == tiled.data,
            "tiled != serial (m={m} k={} n={n} s={s} t={threads} nt={n_tile}, \
             diff {})",
            kb * BLOCK,
            serial.max_abs_diff(&tiled)
        );
        let dense = dense_mm(&x, &w.to_dense(), bias.as_deref(), act);
        let diff = tiled.max_abs_diff(&dense);
        prop_assert!(diff < 1e-3, "tiled vs dense diff {diff} (s={s})");
        Ok(())
    });
}

#[test]
fn prop_pooled_matches_scoped_and_serial() {
    // the differential contract of the persistent execution pool: for
    // random shapes, every supported sparsity, any tile width, and pool
    // worker counts {1, 2, 3, 7}, dispatching the f32 AND int8 kernels
    // through a long-lived ExecPool is bitwise identical to (a) the
    // serial references and (b) the spawn-per-call scoped baselines —
    // the pool changes who computes a stripe, never what is computed.
    // Pools are built once and reused across all cases (the steady-state
    // serving pattern), so this also exercises worker reuse.
    use s4::sparse::pack::{
        qspmm_tiled_into, qspmm_tiled_scoped, spmm_tiled_into, spmm_tiled_scoped,
    };
    use s4::sparse::pool::ExecPool;

    let pools: Vec<ExecPool> = [1usize, 2, 3, 7].iter().map(|&w| ExecPool::new(w)).collect();
    let mut f32_out = Dense2::zeros(0, 0);
    let mut int8_out = Dense2::zeros(0, 0);
    let mut qbuf = Vec::new();
    check("pooled dispatch differential", 48, |g: &mut Gen| {
        let m = g.usize_in(1, 24);
        let kb = g.usize_in(1, 3);
        let n = g.usize_in(1, 40);
        let s = *g.pick(&[1usize, 2, 4, 8, 16, 32]);
        let n_tile = *g.pick(&[3usize, 8, 16, 128]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let pool = &pools[g.usize_in(0, pools.len() - 1)];
        let threads = pool.participants();
        let x = Dense2::randn(m, kb * BLOCK, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(kb * BLOCK, n, seed + 1), s)
            .map_err(|e| e.to_string())?;
        let qb = w.quantize();
        let packed = w.pack_tiled(n_tile);
        let qpacked = qb.pack_tiled(n_tile);
        let bias: Option<Vec<f32>> = if g.bool() {
            Some((0..n).map(|i| (i as f32).sin()).collect())
        } else {
            None
        };
        let act = *g.pick(&[Act::None, Act::Relu, Act::Gelu]);

        let serial = spmm(&x, &w, bias.as_deref(), act);
        spmm_tiled_into(pool, &x, &packed, bias.as_deref(), act, threads, &mut f32_out);
        prop_assert!(
            serial.data == f32_out.data,
            "pooled f32 != serial (m={m} n={n} s={s} nt={n_tile} workers={})",
            pool.workers()
        );
        let scoped = spmm_tiled_scoped(&x, &packed, bias.as_deref(), act, threads);
        prop_assert!(
            scoped.data == f32_out.data,
            "pooled f32 != scoped baseline (m={m} n={n} s={s})"
        );

        let qserial = qspmm(&x, &qb, bias.as_deref(), act);
        qspmm_tiled_into(
            pool,
            &x,
            &qpacked,
            bias.as_deref(),
            act,
            threads,
            &mut qbuf,
            &mut int8_out,
        );
        prop_assert!(
            qserial.data == int8_out.data,
            "pooled int8 != serial (m={m} n={n} s={s} nt={n_tile} workers={})",
            pool.workers()
        );
        let qscoped = qspmm_tiled_scoped(&x, &qpacked, bias.as_deref(), act, threads);
        prop_assert!(
            qscoped.data == int8_out.data,
            "pooled int8 != scoped baseline (m={m} n={n} s={s})"
        );
        Ok(())
    });
}

#[test]
fn prop_tuned_matches_serial_any_plan() {
    // the invariance that makes autotuning safe: for random shapes and
    // EVERY candidate in the default tune grid, dispatching through the
    // plan-parameterized entry points (weights repacked at the plan's
    // tile, stripe cap from the plan) is bitwise identical to the serial
    // f32 and int8 references. A dispatch plan may only change wall
    // clock, never logits — so whatever the tuner picks is correct by
    // construction. Also pins `repacked` as a pure storage permute:
    // repacking equals packing fresh at the target tile.
    use s4::sparse::pack::{qspmm_tiled_into_plan, spmm_tiled_into_plan};
    use s4::sparse::pool::ExecPool;
    use s4::sparse::tune::TuneConfig;

    let pools: Vec<ExecPool> = [1usize, 3].iter().map(|&w| ExecPool::new(w)).collect();
    let grid = TuneConfig::default().candidates();
    let tiles: std::collections::BTreeSet<usize> = grid.iter().map(|c| c.tile_n).collect();
    let mut f32_out = Dense2::zeros(0, 0);
    let mut int8_out = Dense2::zeros(0, 0);
    let mut qbuf = Vec::new();
    check("tuned dispatch differential", 12, |g: &mut Gen| {
        let m = g.usize_in(1, 24);
        let kb = g.usize_in(1, 3);
        let n = g.usize_in(1, 40);
        let s = *g.pick(&[1usize, 2, 4, 8, 16, 32]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let pool = &pools[g.usize_in(0, pools.len() - 1)];
        let x = Dense2::randn(m, kb * BLOCK, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(kb * BLOCK, n, seed + 1), s)
            .map_err(|e| e.to_string())?;
        let qb = w.quantize();
        let bias: Option<Vec<f32>> = if g.bool() {
            Some((0..n).map(|i| (i as f32).sin()).collect())
        } else {
            None
        };
        let act = *g.pick(&[Act::None, Act::Relu, Act::Gelu]);
        let serial = spmm(&x, &w, bias.as_deref(), act);
        let qserial = qspmm(&x, &qb, bias.as_deref(), act);
        let base = w.pack();
        let qbase = qb.pack();
        for &t in &tiles {
            let wt = base.repacked(t);
            let qwt = qbase.repacked(t);
            // repack is a pure permute: identical to packing fresh
            prop_assert!(wt == w.pack_tiled(t), "repacked(f32) != pack_tiled (t={t})");
            prop_assert!(qwt == qb.pack_tiled(t), "repacked(int8) != pack_tiled (t={t})");
            for plan in grid.iter().filter(|c| c.tile_n == t) {
                spmm_tiled_into_plan(pool, &x, &wt, bias.as_deref(), act, *plan, &mut f32_out);
                prop_assert!(
                    serial.data == f32_out.data,
                    "tuned f32 != serial (m={m} n={n} s={s} plan={plan:?} workers={})",
                    pool.workers()
                );
                qspmm_tiled_into_plan(
                    pool,
                    &x,
                    &qwt,
                    bias.as_deref(),
                    act,
                    *plan,
                    &mut qbuf,
                    &mut int8_out,
                );
                prop_assert!(
                    qserial.data == int8_out.data,
                    "tuned int8 != serial (m={m} n={n} s={s} plan={plan:?} workers={})",
                    pool.workers()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_qspmm_tiled_matches_serial_int8_and_tracks_f32() {
    // the differential contract of the quantized engine: for random
    // shapes, every supported sparsity, any thread count and tile width,
    // qspmm_tiled is BIT-IDENTICAL to the serial int8 reference (i32
    // accumulation + identical dequant epilogue expression), and the
    // int8 result stays within quantization noise of the f32 spmm
    // (the same relative-error criterion as qgemm_close_to_f32_gemm,
    // with headroom for few-term reductions at high sparsity)
    check("quantized tiled spmm differential", 60, |g: &mut Gen| {
        let m = g.usize_in(1, 24);
        let kb = g.usize_in(1, 3);
        let n = g.usize_in(1, 40);
        let s = *g.pick(&[1usize, 2, 4, 8, 16, 32]);
        let threads = g.usize_in(1, 4);
        let n_tile = *g.pick(&[3usize, 8, 16, 128]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let x = Dense2::randn(m, kb * BLOCK, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(kb * BLOCK, n, seed + 1), s)
            .map_err(|e| e.to_string())?;
        let qb = w.quantize();
        let bias: Option<Vec<f32>> = if g.bool() {
            Some((0..n).map(|i| (i as f32).sin()).collect())
        } else {
            None
        };
        let act = *g.pick(&[Act::None, Act::Relu, Act::Gelu]);
        let serial = qspmm(&x, &qb, bias.as_deref(), act);
        let tiled = qspmm_tiled(&x, &qb.pack_tiled(n_tile), bias.as_deref(), act, threads);
        prop_assert!(
            serial.data == tiled.data,
            "qtiled != qserial (m={m} k={} n={n} s={s} t={threads} nt={n_tile}, diff {})",
            kb * BLOCK,
            serial.max_abs_diff(&tiled)
        );
        // int8 vs f32: the worst-case quantization-error propagation
        // bound (the spirit of qgemm_close_to_f32_gemm's 2% empirical
        // bound, made analytic so it holds for every random shape);
        // the shared quant_drift_bound covers the activation-free SpMM,
        // ×1.2 covers the activations' Lipschitz constants (Gelu ≈ 1.13)
        let f32_ref = spmm(&x, &w, bias.as_deref(), act);
        let bound = 1.2 * quant_drift_bound(&x, &w, &qb);
        let diff = tiled.max_abs_diff(&f32_ref);
        prop_assert!(
            diff <= bound,
            "int8 drifted from f32: diff {diff} > bound {bound} (s={s})"
        );
        Ok(())
    });
}

#[test]
fn prop_event_sim_bounds() {
    use s4::arch::{EventSim, ResourceId, TaskId};
    check("event sim bounds", 60, |g: &mut Gen| {
        let nres = g.usize_in(1, 4);
        let ntasks = g.usize_in(1, 40);
        let mut sim = EventSim::new(nres);
        let mut ids: Vec<TaskId> = Vec::new();
        let mut total = vec![0.0f64; nres];
        let mut critical_sum = 0.0;
        for i in 0..ntasks {
            let r = g.usize_in(0, nres - 1);
            let secs = g.f64_in(0.0, 1.0);
            // random deps among earlier tasks (keeps the DAG acyclic)
            let mut deps = Vec::new();
            for &prev in ids.iter() {
                if g.usize_in(0, 9) == 0 {
                    deps.push(prev);
                }
            }
            ids.push(sim.add_task(ResourceId(r), secs, &deps, i as u64));
            total[r] += secs;
            critical_sum += secs;
        }
        let tr = sim.run();
        // makespan ≥ busiest resource (work conservation lower bound)
        let busiest = total.iter().cloned().fold(0.0, f64::max);
        prop_assert!(
            tr.makespan >= busiest - 1e-9,
            "makespan {} < busiest {}",
            tr.makespan,
            busiest
        );
        // makespan ≤ serializing everything
        prop_assert!(
            tr.makespan <= critical_sum + 1e-9,
            "makespan {} > total {}",
            tr.makespan,
            critical_sum
        );
        // busy accounting exact
        for r in 0..nres {
            prop_assert!(
                (tr.busy[r] - total[r]).abs() < 1e-9,
                "busy mismatch on {r}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_prune_schedule_monotone_and_bounded() {
    use s4::sparse::prune::PruneSchedule;
    check("prune schedule", 80, |g: &mut Gen| {
        let s = *g.pick(&[2usize, 4, 8, 16, 32]);
        let begin = g.usize_in(0, 100);
        let end = begin + 1 + g.usize_in(1, 1000);
        let sch = PruneSchedule::to_factor(s, begin, end);
        let mut prev = -1.0;
        for t in (0..=end + 100).step_by((end / 20).max(1)) {
            let f = sch.fraction_at(t);
            prop_assert!(f >= prev - 1e-12, "not monotone at t={t}");
            prop_assert!((0.0..=1.0).contains(&f), "fraction {f} out of range");
            let factor = sch.factor_at(t);
            prop_assert!(factor <= s, "factor {factor} exceeds target {s}");
            prev = f;
        }
        prop_assert!(
            (sch.fraction_at(end) - (1.0 - 1.0 / s as f64)).abs() < 1e-9,
            "target not reached"
        );
        Ok(())
    });
}

#[test]
fn prop_fusion_preserves_weighted_work() {
    use s4::graph::fusion::fuse;
    use s4::graph::models;
    check("fusion invariants", 12, |g: &mut Gen| {
        let batch = g.usize_in(1, 8);
        let graph = match g.usize_in(0, 2) {
            0 => models::resnet50(batch, 224),
            1 => models::bert(models::BERT_TINY, batch, 128),
            _ => models::bert(models::BERT_MINI, batch, 128),
        };
        let (fused, stats) = fuse(&graph);
        prop_assert!(stats.ops_after <= stats.ops_before, "fusion grew graph");
        let weighted = |gr: &s4::graph::Graph| -> f64 {
            gr.ops
                .iter()
                .filter(|o| o.kind.sparsifiable())
                .map(|o| o.kind.flops_dense())
                .sum()
        };
        let (a, b) = (weighted(&graph), weighted(&fused));
        prop_assert!((a - b).abs() < 1e-6 * a.max(1.0), "weighted work changed");
        for (i, op) in fused.ops.iter().enumerate() {
            for inp in &op.inputs {
                prop_assert!(inp.0 < i, "topo violated at {i}");
            }
        }
        Ok(())
    });
}
