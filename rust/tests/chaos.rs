//! Chaos suite: the fault-tolerance contract end to end, driven by the
//! deterministic [`fault`](s4::fault) layer over the *real* serving
//! stack ([`CpuSparseBackend`] tiled sparse compute — not an echo stub).
//!
//! What is pinned here (EXPERIMENTS.md §Robustness):
//!
//! * **No ticket lost** — every admitted submission resolves with a
//!   typed response through panics, error bursts, cancels, and
//!   deadlines (`answered() == admitted`, admission slots drain to 0);
//! * **Capacity recovers** — a panicked worker is respawned, the health
//!   breaker re-closes after its probe, and post-fault logits are
//!   **bitwise identical** to a fault-free run (recovery restores the
//!   numerics, not just liveness);
//! * **Connection chaos is contained** — dropped, garbled, and
//!   truncated peers never perturb healthy connections' traffic.

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{CpuSparseBackend, EchoBackend, InferenceBackend, Value};
use s4::coordinator::{
    AdmissionDecision, BatcherConfig, BreakerConfig, BreakerState, Router, RoutingPolicy, Server,
    ServerConfig, SubmitOptions,
};
use s4::fault::{self, FaultPlan, FaultingBackend};
use s4::net::{NetClient, NetServer, NetServerConfig, RetryPolicy};
use s4::prop_assert;
use s4::runtime::Manifest;
use s4::util::prop;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b4", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 4, "seq": 16,
       "inputs": [{"name": "ids", "shape": [4, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

fn tokens(seed: i32) -> Vec<i32> {
    (0..16).map(|t| (seed * 31 + t * 7) % 997).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn cpu_server(cfg: ServerConfig, plan: Option<FaultPlan>) -> Server {
    let m = manifest();
    let inner: Arc<dyn InferenceBackend> = Arc::new(CpuSparseBackend::from_manifest(&m));
    let backend: Arc<dyn InferenceBackend> = match plan {
        Some(p) => Arc::new(FaultingBackend::new(inner, p)),
        None => inner,
    };
    Server::start(cfg, m, Router::new(RoutingPolicy::MaxSparsity), backend)
}

fn serial_cfg(breaker: BreakerConfig) -> ServerConfig {
    ServerConfig {
        // max_batch 1 → one backend call per request, so FaultPlan call
        // indices line up 1:1 with sequential submissions
        batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        workers: 2,
        max_inflight: 32,
        breaker,
        ..Default::default()
    }
}

#[test]
fn fault_storm_then_recovery_restores_bitwise_identical_logits() {
    // ground truth from a fault-free stack
    let clean = cpu_server(serial_cfg(BreakerConfig::default()), None);
    let h = clean.handle();
    let want: Vec<Vec<u32>> = (1..=4)
        .map(|s| {
            let t = h.submit("bert_tiny", vec![Value::tokens(tokens(s))]).unwrap();
            let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
            assert!(r.is_ok(), "{:?}", r.status);
            bits(r.logits())
        })
        .collect();
    clean.shutdown();

    // the storm: a worker-killing panic, then an error burst long enough
    // to trip the breaker (panic + 3 errors = 4 consecutive failures)
    let breaker =
        BreakerConfig { failure_threshold: 3, probe_after_sheds: 1, close_after_probes: 1 };
    let srv = cpu_server(
        serial_cfg(breaker),
        Some(FaultPlan::new().with_panic_at(0).with_error_burst(1, 3)),
    );
    let h = srv.handle();

    // drive the faulted calls; sheds from an open breaker are retried —
    // each shed advances it toward its probe
    let mut faulted_answers = 0;
    let mut sheds = 0;
    let deadline = Instant::now() + Duration::from_secs(30);
    while faulted_answers < 4 {
        assert!(Instant::now() < deadline, "storm never drained");
        match h.submit("bert_tiny", vec![Value::tokens(tokens(1))]) {
            Ok(t) => {
                let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
                if !r.is_ok() {
                    faulted_answers += 1;
                } // a clean answer here just means the probe landed early
            }
            Err(AdmissionDecision::RejectUnhealthy(_)) => sheds += 1,
            Err(other) => panic!("unexpected rejection during the storm: {other:?}"),
        }
    }

    // recovery: keep submitting until the breaker's probe succeeds and
    // the stack serves cleanly again
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "stack never recovered");
        match h.submit("bert_tiny", vec![Value::tokens(tokens(1))]) {
            Ok(t) => {
                let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
                if r.is_ok() {
                    break;
                }
            }
            Err(AdmissionDecision::RejectUnhealthy(_)) => sheds += 1,
            Err(other) => panic!("unexpected rejection during recovery: {other:?}"),
        }
    }
    assert_eq!(h.breaker_state(), BreakerState::Closed, "probe success re-closes");

    // the recovered stack must reproduce the clean stack bit for bit
    for (s, want_bits) in (1..=4).zip(&want) {
        let t = h.submit("bert_tiny", vec![Value::tokens(tokens(s))]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!(r.is_ok(), "post-recovery request {s}: {:?}", r.status);
        assert_eq!(
            &bits(r.logits()),
            want_bits,
            "post-fault logits for payload {s} must be bitwise identical"
        );
    }

    let snap = h.metrics_snapshot();
    assert!(snap.worker_panics >= 1, "{}", snap.report());
    assert!(snap.worker_restarts >= 1, "capacity must be respawned: {}", snap.report());
    assert!(snap.breaker_opens >= 1, "the burst must trip the breaker: {}", snap.report());
    assert_eq!(snap.breaker_shed, sheds, "{}", snap.report());
    assert_eq!(snap.answered(), snap.admitted, "no ticket lost: {}", snap.report());
    assert_eq!(h.inflight(), 0, "every admission slot released");
    srv.shutdown();
}

#[test]
fn connection_chaos_is_invisible_to_healthy_traffic() {
    let srv = cpu_server(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) },
            workers: 2,
            max_inflight: 64,
            ..Default::default()
        },
        None,
    );
    let handle = Arc::new(srv.handle());

    // in-process ground truth per payload
    let expect = |s: i32| {
        let t = handle.submit("bert_tiny", vec![Value::tokens(tokens(s))]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        bits(r.logits())
    };
    let want = [expect(1), expect(2), expect(3)];

    let net = NetServer::bind("127.0.0.1:0", handle.clone(), NetServerConfig::default()).unwrap();
    let addr = net.local_addr();

    // healthy connection established through the retrying front door
    let mut healthy =
        NetClient::connect_retrying(addr, &RetryPolicy::default(), Duration::from_secs(10))
            .unwrap();
    let check = |c: &mut NetClient, s: i32, want: &[u32]| {
        let r = c.call("bert_tiny", vec![Value::tokens(tokens(s))]).unwrap();
        assert!(r.is_ok(), "healthy call {s} under chaos: {:?}", r.status);
        assert_eq!(bits(r.logits()), want, "healthy logits perturbed by chaos peer");
    };

    // interleave every flavor of misbehaving peer with real traffic
    check(&mut healthy, 1, &want[0]);
    fault::net::drop_connection(addr).unwrap();
    check(&mut healthy, 2, &want[1]);
    let reply = fault::net::send_garbage(addr, 0xBAD, 64).unwrap();
    assert!(!reply.is_empty(), "garbage should draw a rejection frame before close");
    check(&mut healthy, 3, &want[2]);
    let frame = s4::net::Frame::Request(s4::net::RequestFrame {
        id: 1,
        model: "bert_tiny".into(),
        priority: SubmitOptions::default().priority,
        deadline: None,
        client_tag: None,
        inputs: vec![Value::tokens(tokens(1))],
    });
    fault::net::send_truncated_frame(addr, &frame, 0.5).unwrap();
    fault::net::drop_connection(addr).unwrap();
    check(&mut healthy, 1, &want[0]);
    check(&mut healthy, 2, &want[1]);

    // the chaos left traces in the wire metrics, not in the traffic
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let snap = net.metrics().snapshot();
        if snap.net.frames_malformed >= 1 && snap.net.conns_closed_on_error >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "chaos peers never recorded: {:?}", snap.net);
        std::thread::sleep(Duration::from_millis(10));
    }

    net.shutdown();
    srv.shutdown();
}

#[test]
fn worker_panic_mid_coalesced_flight_answers_every_waiter_typed() {
    // The cache's single-flight contract under the worst fault: the
    // worker executing a coalesced flight panics. Every waiter — the
    // leader AND all attached followers — must receive the same typed
    // error (no follower hangs on a dead flight), exactly one admission
    // slot is released, and the failed flight is NOT cached: the next
    // identical submission re-executes and succeeds.
    use s4::coordinator::CacheConfig;

    let m = manifest();
    let inner: Arc<dyn InferenceBackend> = Arc::new(EchoBackend::from_manifest(&m));
    let backend = Arc::new(FaultingBackend::new(inner, FaultPlan::new().with_panic_at(0)));
    let srv = Server::start(
        ServerConfig {
            // wide batch window: the leader sits stashed while the
            // followers attach, then call 0 panics under all four waiters
            batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(150) },
            workers: 1,
            max_inflight: 32,
            cache: Some(CacheConfig::default()),
            ..Default::default()
        },
        m,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();

    let payload = || vec![Value::tokens(tokens(3))];
    let leader = h.submit("bert_tiny", payload()).unwrap();
    let followers: Vec<_> = (0..3).map(|_| h.submit("bert_tiny", payload()).unwrap()).collect();

    let r = leader.wait_timeout(Duration::from_secs(10)).unwrap();
    assert!(!r.is_ok(), "leader of the panicked flight must fail typed");
    assert!(
        r.error_message().unwrap_or("").contains("worker panicked"),
        "leader error: {:?}",
        r.status
    );
    for (i, f) in followers.iter().enumerate() {
        let r = f.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!(!r.is_ok(), "follower {i} must share the flight's typed error");
        assert!(
            r.error_message().unwrap_or("").contains("worker panicked"),
            "follower {i} error: {:?}",
            r.status
        );
    }

    let snap = h.metrics_snapshot();
    assert_eq!(snap.admitted, 1, "one flight admitted: {}", snap.report());
    assert_eq!(snap.coalesced, 3, "three followers attached: {}", snap.report());
    assert_eq!(snap.answered(), snap.admitted, "no ticket lost: {}", snap.report());
    assert_eq!(snap.served(), 4, "all four waiters answered: {}", snap.report());
    assert_eq!(snap.cache_hits, 0, "a failed flight must never be cached");
    assert_eq!(h.inflight(), 0, "exactly one admission slot released");

    // the error is not replayed: the retry re-executes (fault plan only
    // panics call 0) and succeeds with real logits
    let retry = h.submit("bert_tiny", payload()).unwrap();
    let r = retry.wait_timeout(Duration::from_secs(10)).unwrap();
    assert!(r.is_ok(), "retry after the panicked flight: {:?}", r.status);
    assert!(!r.logits().is_empty(), "retry must carry real output");
    let snap = h.metrics_snapshot();
    assert_eq!(snap.admitted, 2, "retry re-executes, not replayed: {}", snap.report());
    assert_eq!(snap.cache_hits, 0, "{}", snap.report());
    srv.shutdown();
}

#[test]
fn every_submission_resolves_under_seeded_random_chaos() {
    // Property (PR 7 satellite): N submissions under a random mix of
    // injected panics/errors/slow calls, client cancels, and tight
    // deadlines — every ticket resolves with a typed response, the
    // accounting balances, and no admission slot leaks. Echo backend:
    // the property is about accounting, not numerics (those are pinned
    // above), and it keeps 200+ chaotic requests fast.
    prop::check("no_ticket_lost_under_chaos", 8, |g| {
        let n = g.usize_in(8, 24);
        let plan = FaultPlan::seeded(
            g.rng.next_u64(),
            n as u64 * 2,
            g.f64_in(0.1, 0.4),
            Duration::from_millis(1),
        );
        let m = manifest();
        let inner: Arc<dyn InferenceBackend> = Arc::new(EchoBackend::from_manifest(&m));
        let backend = Arc::new(FaultingBackend::new(inner, plan));
        let srv = Server::start(
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch: g.usize_in(1, 4),
                    max_wait: Duration::from_millis(1),
                },
                workers: g.usize_in(1, 3),
                max_inflight: 64,
                // small thresholds so the random storm can exercise every
                // breaker transition within one case
                breaker: BreakerConfig {
                    failure_threshold: 2,
                    probe_after_sheds: 1,
                    close_after_probes: 1,
                },
                ..Default::default()
            },
            m,
            Router::new(RoutingPolicy::MaxSparsity),
            backend,
        );
        let h = srv.handle();

        let mut tickets = Vec::new();
        let mut rejected = 0u64;
        for i in 0..n {
            let mut opts = SubmitOptions::default();
            if g.bool() {
                // deadlines from "already dead" to "comfortably alive"
                opts = opts.with_deadline(Duration::from_millis(g.usize_in(0, 50) as u64));
            }
            match h.submit_with("bert_tiny", vec![Value::tokens(tokens(i as i32))], opts) {
                Ok(t) => {
                    if g.bool() && g.bool() {
                        t.cancel(); // cancel ~25% after submission
                    }
                    tickets.push(t);
                }
                Err(_) => rejected += 1, // shed/reject is a resolution too
            }
        }

        // the contract: every admitted ticket resolves, whatever happened
        for (i, t) in tickets.iter().enumerate() {
            let r = t.wait_timeout(Duration::from_secs(10));
            prop_assert!(r.is_ok(), "ticket {i}/{n} never resolved: {:?}", r.err());
        }
        let snap = h.metrics_snapshot();
        prop_assert!(
            snap.answered() == snap.admitted,
            "answered {} != admitted {} (rejected {rejected}): {}",
            snap.answered(),
            snap.admitted,
            snap.report()
        );
        prop_assert!(
            snap.admitted as usize == tickets.len(),
            "admitted {} != issued tickets {}",
            snap.admitted,
            tickets.len()
        );
        prop_assert!(h.inflight() == 0, "leaked admission slots: {}", h.inflight());
        srv.shutdown();
        Ok(())
    });
}
