//! Runs the shared backend conformance suite (`s4::backend::conformance`)
//! against every in-tree `InferenceBackend` that works without external
//! dependencies. The suite pins spec introspection, shape/dtype
//! validation, error paths (unknown artifacts are `Err`, never a panic),
//! and output determinism — one manifest spanning a token model and an
//! image model, so both modalities are covered on every backend.

use s4::backend::{conformance, CpuSparseBackend, EchoBackend, Precision, SimBackend, Value};
use s4::runtime::Manifest;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "a", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "b", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 16,
       "inputs": [{"name": "ids", "shape": [8, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [8, 2], "dtype": "f32"}]},
      {"name": "resnet50_s8_b4", "file": "c", "family": "resnet",
       "model": "resnet50", "sparsity": 8, "batch": 4, "seq": 0,
       "inputs": [{"name": "images", "shape": [4, 3, 8, 8], "dtype": "f32"}],
       "outputs": [{"name": "logits", "shape": [4, 10], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

#[test]
fn echo_backend_conforms() {
    let m = manifest();
    conformance::run_all(&EchoBackend::from_manifest(&m), &m);
}

#[test]
fn sim_backend_conforms() {
    let m = manifest();
    conformance::run_all(&SimBackend::from_manifest(&m, 1e-4), &m);
}

#[test]
fn cpu_sparse_backend_conforms() {
    // the real-compute backend honors the identical contract — including
    // determinism, which the tiled kernel guarantees at any thread count
    let m = manifest();
    conformance::run_all(&CpuSparseBackend::from_manifest(&m), &m);
    conformance::run_all(&CpuSparseBackend::with_threads(&m, 3), &m);
}

#[test]
fn cpu_sparse_backend_int8_conforms() {
    // the quantized serving path honors the same contract: spec
    // introspection, validation, error paths, and determinism (i32
    // accumulation is order-independent, so any thread count agrees)
    let m = manifest();
    conformance::run_all(&CpuSparseBackend::with_precision(&m, Precision::Int8), &m);
    conformance::run_all(
        &CpuSparseBackend::with_threads_precision(&m, 3, Some(Precision::Int8)),
        &m,
    );
}

#[test]
fn cpu_sparse_int8_logits_within_derived_tolerance_of_f32() {
    // accuracy half of the int8 serving contract: for every artifact
    // (token and image modalities), Int8 logits stay within the
    // per-layer max_error_bound-derived tolerance of the F32 logits
    let m = manifest();
    let f = CpuSparseBackend::with_precision(&m, Precision::F32);
    let q = CpuSparseBackend::with_precision(&m, Precision::Int8);
    for a in &m.artifacts {
        let inputs: Vec<Value> = a
            .inputs
            .iter()
            .map(|s| match s.dtype.as_str() {
                "s32" => Value::I32((0..s.elems() as i32).map(|x| x % 101).collect()),
                _ => Value::F32((0..s.elems()).map(|x| (x as f32 * 0.37).sin()).collect()),
            })
            .collect();
        let of = f.run_batch(&a.name, &inputs).unwrap();
        let oq = q.run_batch(&a.name, &inputs).unwrap();
        let tol = q.int8_tolerance(&a.name).unwrap();
        assert!(tol > 0.0 && tol < 0.5, "{}: tolerance sane ({tol})", a.name);
        for (vf, vq) in of.iter().zip(&oq) {
            let (lf, lq) = (vf.as_f32().unwrap(), vq.as_f32().unwrap());
            let num: f32 = lf.iter().zip(lq).map(|(x, y)| (x - y) * (x - y)).sum();
            let den: f32 = lf.iter().map(|v| v * v).sum();
            let rel = if den == 0.0 { 0.0 } else { (num / den).sqrt() };
            assert!(rel <= tol, "{}: int8 rel err {rel} > tolerance {tol}", a.name);
        }
    }
}
