//! Runs the shared backend conformance suite (`s4::backend::conformance`)
//! against every in-tree `InferenceBackend` that works without external
//! dependencies. The suite pins spec introspection, shape/dtype
//! validation, error paths (unknown artifacts are `Err`, never a panic),
//! and output determinism — one manifest spanning a token model and an
//! image model, so both modalities are covered on every backend.

use s4::backend::{conformance, CpuSparseBackend, EchoBackend, SimBackend};
use s4::runtime::Manifest;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "a", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "b", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 16,
       "inputs": [{"name": "ids", "shape": [8, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [8, 2], "dtype": "f32"}]},
      {"name": "resnet50_s8_b4", "file": "c", "family": "resnet",
       "model": "resnet50", "sparsity": 8, "batch": 4, "seq": 0,
       "inputs": [{"name": "images", "shape": [4, 3, 8, 8], "dtype": "f32"}],
       "outputs": [{"name": "logits", "shape": [4, 10], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

#[test]
fn echo_backend_conforms() {
    let m = manifest();
    conformance::run_all(&EchoBackend::from_manifest(&m), &m);
}

#[test]
fn sim_backend_conforms() {
    let m = manifest();
    conformance::run_all(&SimBackend::from_manifest(&m, 1e-4), &m);
}

#[test]
fn cpu_sparse_backend_conforms() {
    // the real-compute backend honors the identical contract — including
    // determinism, which the tiled kernel guarantees at any thread count
    let m = manifest();
    conformance::run_all(&CpuSparseBackend::from_manifest(&m), &m);
    conformance::run_all(&CpuSparseBackend::with_threads(&m, 3), &m);
}
