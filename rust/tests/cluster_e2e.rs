//! End-to-end: the cluster router tier over a real multi-node fleet.
//!
//! Boots N in-process serving nodes ([`spawn_local_cluster`]: full
//! coordinator + [`NetServer`] each), fronts them with a
//! [`RouterServer`], and pins the layer's headline claims:
//!
//! * **transparency** — logits served through the router (over two wire
//!   hops) are bitwise identical to direct in-process submission on a
//!   node, and the stock open-loop load harness drives the router
//!   unchanged;
//! * **failover** — killing a node mid-load sheds onto the surviving
//!   replicas; no ticket is lost, and both the router's ledger and every
//!   node's ledger still reconcile (`answered() == admitted`);
//! * **typed degradation** — when every replica is down the router sheds
//!   retryable instead of hanging or erroring untyped.
//!
//! Recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;
use std::time::Duration;

use s4::backend::{CpuSparseBackend, EchoBackend, InferenceBackend, Value};
use s4::cluster::{spawn_local_cluster, RouterConfig, RouterServer};
use s4::coordinator::{BatcherConfig, Router, RoutingPolicy, ServerConfig};
use s4::net::{
    run_open_loop, run_open_loop_local, LoadSpec, NetClient, NetServer, NetServerConfig,
    RetryPolicy, WireStatus,
};
use s4::runtime::Manifest;

fn manifest() -> Manifest {
    let text = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
       "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b4", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 4, "seq": 16,
       "inputs": [{"name": "ids", "shape": [4, 16], "dtype": "s32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "f32"}]}
    ]}"#;
    Manifest::parse(std::path::Path::new("/tmp"), text).unwrap()
}

fn node_cfg() -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        workers: 2,
        max_inflight: 128,
        ..Default::default()
    }
}

/// Real sparse compute per node — weights are seeded from the model
/// name, so every node computes identical logits for identical tokens.
fn cpu_node(_i: usize) -> (ServerConfig, Manifest, Router, Arc<dyn InferenceBackend>) {
    let m = manifest();
    let backend: Arc<dyn InferenceBackend> = Arc::new(CpuSparseBackend::from_manifest(&m));
    (node_cfg(), m, Router::new(RoutingPolicy::MaxSparsity), backend)
}

/// Instant reflection per node — for load tests where throughput, not
/// numerics, is under test.
fn echo_node(_i: usize) -> (ServerConfig, Manifest, Router, Arc<dyn InferenceBackend>) {
    let m = manifest();
    let backend: Arc<dyn InferenceBackend> = Arc::new(EchoBackend::from_manifest(&m));
    (node_cfg(), m, Router::new(RoutingPolicy::MaxSparsity), backend)
}

fn fast_retry() -> RetryPolicy {
    RetryPolicy { attempts: 1, connect_timeout: Duration::from_millis(250), ..Default::default() }
}

fn tokens(seed: i32) -> Vec<i32> {
    (0..16).map(|t| (seed * 31 + t * 7) % 997).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn routed_logits_are_bitwise_identical_to_direct_submission() {
    let cluster = spawn_local_cluster(3, cpu_node).unwrap();
    let router = RouterServer::new(
        cluster.spec(),
        RouterConfig { replication: 3, retry: fast_retry(), ..Default::default() },
    )
    .unwrap();
    // the router behind its own socket: client → router wire hop →
    // node wire hop → sparse compute → back through both hops
    let rnet = Arc::new(
        NetServer::bind("127.0.0.1:0", Arc::new(router.clone()), NetServerConfig::default())
            .unwrap(),
    );
    let mut client = NetClient::connect(rnet.local_addr(), Duration::from_secs(10)).unwrap();
    for seed in 0..6 {
        let direct = cluster.nodes[0]
            .handle
            .submit("bert_tiny", vec![Value::tokens(tokens(seed))])
            .unwrap()
            .wait()
            .unwrap();
        assert!(direct.is_ok(), "direct submission failed: {:?}", direct.status);
        let frame = client.call("bert_tiny", vec![Value::tokens(tokens(seed))]).unwrap();
        assert!(
            matches!(frame.status, WireStatus::Ok),
            "routed submission failed: {:?}",
            frame.status
        );
        // whichever replica served, the logits must match node 0's bits
        assert_eq!(
            bits(frame.logits()),
            bits(direct.logits()),
            "seed {seed}: routed logits drifted from direct submission"
        );
    }
    let snap = router.metrics_snapshot();
    assert_eq!(snap.cluster.forwards, 6);
    assert_eq!(snap.answered(), snap.admitted, "router ledger reconciles");
    rnet.shutdown();
    cluster.shutdown();
}

#[test]
fn node_kill_mid_load_fails_over_and_loses_no_ticket() {
    let mut cluster = spawn_local_cluster(3, echo_node).unwrap();
    let router = Arc::new(
        RouterServer::new(
            cluster.spec(),
            RouterConfig { replication: 3, retry: fast_retry(), ..Default::default() },
        )
        .unwrap(),
    );
    let spec = LoadSpec {
        tokens: tokens(3),
        rate_rps: 300.0,
        duration: Duration::from_millis(1500),
        connections: 2,
        drain_grace: Duration::from_secs(15),
        seed: 0xC1C1,
        ..LoadSpec::default()
    };
    let loader = {
        let router = router.clone();
        std::thread::spawn(move || run_open_loop_local(&router, &spec).unwrap())
    };
    // kill one node mid-load: its socket drains in-flight tickets, then
    // the port refuses — requests whose rotated primary it was must fail
    // over to the survivors
    std::thread::sleep(Duration::from_millis(500));
    cluster.nodes[0].kill();
    // client-side chaos at a survivor's socket boundary must not disturb
    // serving either
    let _ = s4::fault::net::send_garbage(cluster.nodes[1].addr, 0xBAD5EED, 64);
    let report = loader.join().unwrap();

    assert_eq!(report.lost, 0, "no ticket lost across the node kill: {report:?}");
    assert!(report.completed() > 0, "load must have served: {report:?}");
    let snap = router.metrics_snapshot();
    assert_eq!(snap.answered(), snap.admitted, "router ledger reconciles: {snap:?}");
    assert!(
        snap.cluster.failovers >= 1,
        "a kill mid-load must produce failovers: {snap:?}"
    );
    // the dead node's per-node counters stop growing; survivors carried
    // the rest of the run
    let survivors: u64 = snap.cluster.by_node[1..].iter().map(|n| n.forwards).sum();
    assert!(survivors > 0, "survivors served nothing: {snap:?}");
    assert_eq!(
        snap.cluster.by_node.iter().map(|n| n.forwards).sum::<u64>(),
        snap.cluster.forwards,
        "per-node counters must sum to the aggregate"
    );
    // every node's own ledger reconciles too — the killed node drained
    // its in-flight work before dying, the survivors answered the rest
    for node in &cluster.nodes {
        let s = node.handle.metrics_snapshot();
        assert_eq!(s.answered(), s.admitted, "node {} ledger reconciles", node.id);
    }
    cluster.shutdown();
}

#[test]
fn router_is_wire_transparent_to_the_stock_load_harness() {
    let cluster = spawn_local_cluster(2, echo_node).unwrap();
    let router = RouterServer::new(
        cluster.spec(),
        RouterConfig { replication: 2, retry: fast_retry(), ..Default::default() },
    )
    .unwrap();
    let net = Arc::new(
        NetServer::bind("127.0.0.1:0", Arc::new(router.clone()), NetServerConfig::default())
            .unwrap(),
    );
    let addr = net.local_addr();
    // chaos first: garbage and a dropped connection at the router's own
    // socket boundary — contained per connection, ledger untouched
    s4::fault::net::send_garbage(addr, 0x6A6A, 128).unwrap();
    s4::fault::net::drop_connection(addr).unwrap();
    // the stock TCP load harness, pointed at the router as if it were a
    // single net-serve node
    let spec = LoadSpec {
        tokens: tokens(7),
        rate_rps: 200.0,
        duration: Duration::from_millis(1200),
        connections: 2,
        drain_grace: Duration::from_secs(15),
        seed: 0x7E57,
        ..LoadSpec::default()
    };
    let report = run_open_loop(addr, &spec).unwrap();
    assert_eq!(report.lost, 0, "wire clients must not lose tickets: {report:?}");
    assert!(report.completed() > 0, "load must have served: {report:?}");
    let snap = router.metrics_snapshot();
    assert_eq!(snap.answered(), snap.admitted, "router ledger reconciles: {snap:?}");
    assert!(
        snap.cluster.forwards >= report.completed(),
        "every completion rode a forward: {snap:?}"
    );
    assert!(
        snap.net.frames_malformed >= 1,
        "the garbage peer must be counted at the router's socket boundary: {snap:?}"
    );
    assert_eq!(snap.cluster.by_node.len(), 2, "per-node rows surfaced: {snap:?}");
    net.shutdown();
    cluster.shutdown();
}
