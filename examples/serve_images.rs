//! Vision serving driver — proof that the unified inference API is not
//! text-only.
//!
//! One coordinator, one `InferenceBackend`, two modalities: ResNet-style
//! image-classification requests (f32 pixel tensors) and BERT-style token
//! requests (s32 ids) arrive interleaved; the dynamic batcher keeps the
//! models separate, the router picks sparsity/batch variants per model,
//! and spec-driven padding/demux handles both payload types through the
//! identical path. Runs on the simulator-paced backend by default
//! (`--backend cpu` swaps in [`CpuSparseBackend`] for real sparse
//! compute through the tiled SpMM engine), so no PJRT or AOT artifacts
//! are needed.
//!
//! ```bash
//! cargo run --release --example serve_images -- --requests 48 --rate 200
//! cargo run --release --example serve_images -- --backend cpu
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::{CpuSparseBackend, InferenceBackend, SimBackend, Value};
use s4::coordinator::{
    BatcherConfig, ResponseStatus, Router, RoutingPolicy, Server, ServerConfig, SubmitOptions,
};
use s4::runtime::Manifest;
use s4::util::cli::Args;
use s4::util::rng::Xoshiro256;
use s4::util::stats::Summary;

/// In-memory manifest: ResNet-50 image variants (downscaled 32×32 inputs
/// so the example is instant) next to a BERT token variant — the mixed
/// fleet a single S4 card serves in the paper's deployment story.
const MANIFEST: &str = r#"{"artifacts": [
  {"name": "resnet50_s1_b1", "file": "r1", "family": "resnet",
   "model": "resnet50", "sparsity": 1, "batch": 1, "seq": 0,
   "inputs": [{"name": "images", "shape": [1, 3, 32, 32], "dtype": "f32"}],
   "outputs": [{"name": "logits", "shape": [1, 1000], "dtype": "f32"}]},
  {"name": "resnet50_s8_b8", "file": "r8", "family": "resnet",
   "model": "resnet50", "sparsity": 8, "batch": 8, "seq": 0,
   "inputs": [{"name": "images", "shape": [8, 3, 32, 32], "dtype": "f32"}],
   "outputs": [{"name": "logits", "shape": [8, 1000], "dtype": "f32"}]},
  {"name": "bert_tiny_s8_b8", "file": "b8", "family": "bert",
   "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 128,
   "inputs": [{"name": "ids", "shape": [8, 128], "dtype": "s32"}],
   "outputs": [{"name": "logits", "shape": [8, 2], "dtype": "f32"}]}
]}"#;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("requests", 48)?;
    let rate = args.get_f64("rate", 200.0)?;
    let time_scale = args.get_f64("time-scale", 0.01)?;

    let manifest = Manifest::parse(std::path::Path::new("/tmp"), MANIFEST)?;
    let backend: Arc<dyn InferenceBackend> = match args.get_or("backend", "sim") {
        "cpu" => Arc::new(CpuSparseBackend::from_manifest(&manifest)),
        "sim" => Arc::new(SimBackend::from_manifest(&manifest, time_scale)),
        b => anyhow::bail!("unknown backend {b:?} (cpu | sim)"),
    };
    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            workers: 2,
            max_inflight: 512,
            ..Default::default()
        },
        manifest,
        Router::new(RoutingPolicy::MaxSparsity),
        backend,
    );
    let h = srv.handle();

    eprintln!("serving {n} mixed image/token requests at ~{rate}/s");
    let mut rng = Xoshiro256::seed_from_u64(11);
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for i in 0..n {
        std::thread::sleep(Duration::from_secs_f64(rng.next_exp(rate)));
        // 2 in 3 requests are images (bulk-ish analytics traffic); the
        // token sequences are the latency-critical interactive tier
        let submitted = if i % 3 != 0 {
            let pixels: Vec<f32> =
                (0..3 * 32 * 32).map(|_| rng.next_below(256) as f32 / 255.0).collect();
            h.submit("resnet50", vec![Value::F32(pixels)])
        } else {
            let tokens: Vec<i32> = (0..128).map(|_| rng.next_below(1024) as i32).collect();
            h.submit_with(
                "bert_tiny",
                vec![Value::tokens(tokens)],
                SubmitOptions::interactive().with_deadline(Duration::from_secs(30)),
            )
        };
        match submitted {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }

    let mut lat_ms = Vec::new();
    let mut shed = 0usize;
    let mut by_artifact: std::collections::BTreeMap<String, usize> = Default::default();
    let mut top1: std::collections::BTreeMap<usize, usize> = Default::default();
    for t in tickets {
        let r = t.wait_timeout(Duration::from_secs(60))?;
        match r.status {
            ResponseStatus::Expired | ResponseStatus::Cancelled => {
                shed += 1;
                continue;
            }
            _ => anyhow::ensure!(r.is_ok(), "request failed: {:?}", r.status),
        }
        lat_ms.push(r.latency_us as f64 / 1e3);
        // argmax over the returned logits — the classification answer
        let logits = r.logits();
        if let Some((cls, _)) = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        {
            *top1.entry(cls).or_default() += 1;
        }
        *by_artifact.entry(r.served_by.to_string()).or_default() += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = Summary::of(&lat_ms);
    println!("\n=== serve_images results ===");
    println!(
        "completed:   {} / {n} ({rejected} rejected, {shed} shed)",
        lat_ms.len()
    );
    println!("wall time:   {wall:.2} s  ({:.1} req/s)", lat_ms.len() as f64 / wall);
    println!(
        "latency ms:  p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        s.p50, s.p90, s.p99, s.max
    );
    println!("served by:");
    for (a, c) in by_artifact {
        println!("  {a:<24} {c}");
    }
    println!("distinct top-1 classes: {}", top1.len());
    println!("metrics:     {}", h.metrics_snapshot().report());
    srv.shutdown();
    Ok(())
}
