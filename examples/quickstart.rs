//! Quickstart: the 60-second tour of the s4 crate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the three things a downstream user does most: inspect the chip,
//! prune into the hardware format, and simulate a model at a sparsity
//! level against the T4 baseline — the minimal path to Fig. 2's numbers.

use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::{report, simulate, Target};
use s4::sparse::format::BlockBalanced;
use s4::sparse::matmul::{spmm, Act};
use s4::sparse::tensor::{DType, Dense2};

fn main() -> anyhow::Result<()> {
    // 1. The chip (paper §2's parameters, validated).
    let chip = AntoumConfig::s4();
    chip.validate()?;
    println!(
        "Antoum: {} subsystems, {:.0} sparse-equivalent INT8 TOPS @ {} W\n",
        chip.subsystems,
        chip.equivalent_tops(DType::Int8, 32),
        chip.tdp_w
    );

    // 2. Sparse tensor substrate: prune a weight matrix into the hardware
    //    format and run the reference sparse matmul.
    let w = Dense2::randn(256, 64, 42);
    let sparse_w = BlockBalanced::from_dense(&w, 8)?;
    println!(
        "block-balanced 8x: {} → {} bytes ({}x smaller)",
        sparse_w.dense_bytes(DType::Bf16),
        sparse_w.bytes(DType::Bf16),
        sparse_w.dense_bytes(DType::Bf16) / sparse_w.bytes(DType::Bf16)
    );
    let x = Dense2::randn(4, 256, 43);
    let y = spmm(&x, &sparse_w, None, Act::Gelu);
    println!("spmm output: {}x{} (first = {:.4})\n", y.rows, y.cols, y.at(0, 0));

    // 3. Simulate BERT-base on S4 at increasing sparsity vs the T4 model.
    let g = models::bert(models::BERT_BASE, 16, 128);
    let t4 = simulate(&g, Target::t4());
    println!("bert_base batch=16, seq=128:");
    println!("  T4 dense       : {:>8.0} seq/s", t4.throughput);
    for s in [1usize, 8, 32] {
        let r = simulate(&g, Target::antoum(&chip, s));
        println!(
            "  S4 sparsity {s:>2} : {:>8.0} seq/s  ({:.2}x vs T4)",
            r.throughput,
            r.throughput / t4.throughput
        );
    }
    println!();

    // 4. Engine-time breakdown of one configuration.
    let r = simulate(&g, Target::antoum(&chip, 8));
    print!("{}", report::breakdown_table(&r));
    Ok(())
}
