//! END-TO-END serving driver — the repo's required full-stack proof.
//!
//! Loads the real AOT artifacts (`make artifacts`), compiles them on the
//! PJRT CPU client, and serves Poisson-arriving classification requests
//! through the full SparseRT stack (admission → dynamic batcher → router →
//! PJRT execution), reporting latency percentiles and throughput per
//! routing policy. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_bert -- \
//!     --requests 64 --rate 50 --policy max
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::coordinator::{
    Backend, BatcherConfig, Router, RoutingPolicy, Server, ServerConfig,
};
use s4::runtime::{default_artifact_dir, Executor, Manifest, Value};
use s4::util::cli::Args;
use s4::util::rng::Xoshiro256;
use s4::util::stats::Summary;

/// PJRT-backed serving backend. The PJRT client is not `Send`/`Sync`
/// (Rc-based internals), so a dedicated executor thread owns it; workers
/// submit execution jobs over a channel. All artifacts are precompiled at
/// startup — the request path is pure execution.
struct PjrtBackend {
    tx: std::sync::Mutex<std::sync::mpsc::Sender<Job>>,
    /// artifact → (seq, classes), snapshotted from the manifest
    meta: std::collections::HashMap<String, (usize, usize)>,
}

type Job = (String, Vec<i32>, std::sync::mpsc::Sender<anyhow::Result<Vec<f32>>>);

impl PjrtBackend {
    fn new(m: &Manifest) -> anyhow::Result<PjrtBackend> {
        let meta = m
            .artifacts
            .iter()
            .map(|a| {
                let classes = a.outputs.first().map(|o| o.shape[1]).unwrap_or(2);
                (a.name.clone(), (a.seq.max(1), classes))
            })
            .collect();
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let m2 = m.clone();
        // readiness signal: compilation happens before serving starts
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<anyhow::Result<usize>>();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let mut ex = match Executor::cpu() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                match ex.load_all(&m2) {
                    Ok(n) => {
                        let _ = ready_tx.send(Ok(n));
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                while let Ok((artifact, tokens, resp)) = rx.recv() {
                    let result = ex
                        .loaded(&artifact)
                        .ok_or_else(|| anyhow::anyhow!("artifact {artifact} not loaded"))
                        .and_then(|model| model.run(&[Value::I32(tokens)]))
                        .map(|out| out.into_iter().next().unwrap());
                    let _ = resp.send(result);
                }
            })?;
        let n = ready_rx.recv()??;
        eprintln!("compiled {n} artifacts on the PJRT executor thread");
        Ok(PjrtBackend { tx: std::sync::Mutex::new(tx), meta })
    }
}

impl Backend for PjrtBackend {
    fn run(&self, artifact: &str, _capacity: usize, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let (rtx, rrx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send((artifact.to_string(), tokens.to_vec(), rtx))
            .map_err(|_| anyhow::anyhow!("pjrt executor thread gone"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("pjrt executor thread gone"))?
    }

    fn seq_len(&self, artifact: &str) -> usize {
        self.meta.get(artifact).map(|&(s, _)| s).unwrap_or(128)
    }

    fn classes(&self, artifact: &str) -> usize {
        self.meta.get(artifact).map(|&(_, c)| c).unwrap_or(2)
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("requests", 64)?;
    let rate = args.get_f64("rate", 50.0)?;
    let policy = match args.get_or("policy", "max") {
        "max" => RoutingPolicy::MaxSparsity,
        "dense" => RoutingPolicy::Dense,
        p if p.starts_with("fixed:") => RoutingPolicy::Fixed(p[6..].parse()?),
        p => anyhow::bail!("unknown policy {p:?} (max | dense | fixed:S)"),
    };

    let manifest = Manifest::load(&default_artifact_dir())?;
    let backend = Arc::new(PjrtBackend::new(&manifest)?);
    let vocab = 1024i32; // bert_tiny vocab (see python/compile/model.py)

    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
            },
            workers: 2,
            max_inflight: 512,
        },
        manifest,
        Router::new(policy),
        backend,
    );
    let h = srv.handle();

    eprintln!("serving {n} requests at ~{rate}/s, policy {policy:?}");
    let mut rng = Xoshiro256::seed_from_u64(7);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut rejected = 0;
    for _ in 0..n {
        std::thread::sleep(Duration::from_secs_f64(rng.next_exp(rate)));
        let tokens: Vec<i32> = (0..128).map(|_| rng.next_below(vocab as u64) as i32).collect();
        match h.submit("bert_tiny", tokens) {
            Ok((_, rx)) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    let mut lat_ms = Vec::new();
    let mut by_artifact: std::collections::BTreeMap<String, usize> = Default::default();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(r.ok, "request failed: {:?}", r.error);
        lat_ms.push(r.latency_us as f64 / 1e3);
        *by_artifact.entry(r.served_by).or_default() += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = Summary::of(&lat_ms);
    println!("\n=== serve_bert results (policy {policy:?}) ===");
    println!("completed:   {} / {n} ({} rejected)", lat_ms.len(), rejected);
    println!("wall time:   {wall:.2} s  ({:.1} req/s)", lat_ms.len() as f64 / wall);
    println!(
        "latency ms:  p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        s.p50, s.p90, s.p99, s.max
    );
    println!("served by:");
    for (a, c) in by_artifact {
        println!("  {a:<24} {c}");
    }
    println!("metrics:     {}", h.metrics.report());
    srv.shutdown();
    Ok(())
}
