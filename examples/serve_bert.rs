//! END-TO-END serving driver (feature `pjrt`) — the repo's required
//! full-stack proof on the text workload.
//!
//! Loads the real AOT artifacts (`make artifacts`), compiles them on the
//! PJRT CPU client via [`PjrtServingBackend`] (the unified
//! `InferenceBackend` implementation owning the executor thread), and
//! serves Poisson-arriving classification requests through the full
//! SparseRT stack (admission → dynamic batcher → router → PJRT
//! execution), reporting latency percentiles and throughput per routing
//! policy. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example serve_bert -- \
//!     --requests 64 --rate 50 --policy max
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use s4::backend::Value;
use s4::coordinator::{BatcherConfig, Router, RoutingPolicy, Server, ServerConfig};
use s4::runtime::{default_artifact_dir, Manifest, PjrtServingBackend};
use s4::util::cli::Args;
use s4::util::rng::Xoshiro256;
use s4::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("requests", 64)?;
    let rate = args.get_f64("rate", 50.0)?;
    let policy = match args.get_or("policy", "max") {
        "max" => RoutingPolicy::MaxSparsity,
        "dense" => RoutingPolicy::Dense,
        p if p.starts_with("fixed:") => RoutingPolicy::Fixed(p[6..].parse()?),
        p => anyhow::bail!("unknown policy {p:?} (max | dense | fixed:S)"),
    };

    let manifest = Manifest::load(&default_artifact_dir())?;
    let backend = Arc::new(PjrtServingBackend::new(&manifest)?);
    let vocab = 1024i32; // bert_tiny vocab (see python/compile/model.py)

    let srv = Server::start(
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
            },
            workers: 2,
            max_inflight: 512,
            ..Default::default()
        },
        manifest,
        Router::new(policy),
        backend,
    );
    let h = srv.handle();

    eprintln!("serving {n} requests at ~{rate}/s, policy {policy:?}");
    let mut rng = Xoshiro256::seed_from_u64(7);
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let mut rejected = 0;
    for _ in 0..n {
        std::thread::sleep(Duration::from_secs_f64(rng.next_exp(rate)));
        let tokens: Vec<i32> = (0..128).map(|_| rng.next_below(vocab as u64) as i32).collect();
        match h.submit("bert_tiny", vec![Value::tokens(tokens)]) {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }
    let mut lat_ms = Vec::new();
    let mut by_artifact: std::collections::BTreeMap<String, usize> = Default::default();
    for t in tickets {
        let r = t.wait_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(r.is_ok(), "request failed: {:?}", r.status);
        lat_ms.push(r.latency_us as f64 / 1e3);
        *by_artifact.entry(r.served_by.to_string()).or_default() += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = Summary::of(&lat_ms);
    println!("\n=== serve_bert results (policy {policy:?}) ===");
    println!("completed:   {} / {n} ({} rejected)", lat_ms.len(), rejected);
    println!("wall time:   {wall:.2} s  ({:.1} req/s)", lat_ms.len() as f64 / wall);
    println!(
        "latency ms:  p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        s.p50, s.p90, s.p99, s.max
    );
    println!("served by:");
    for (a, c) in by_artifact {
        println!("  {a:<24} {c}");
    }
    println!("metrics:     {}", h.metrics_snapshot().report());
    srv.shutdown();
    Ok(())
}
