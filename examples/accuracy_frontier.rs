//! Figure 3 driver: accuracy & throughput of dense models on T4 vs their
//! sparse equivalents on S4 — the "a larger sparse model dominates a
//! smaller dense model" frontier.
//!
//! Accuracy comes from `artifacts/accuracy.json` when the Python
//! sparsification experiments have run (`python -m compile.train --fig3`);
//! otherwise the published top-1/GLUE numbers the paper's Fig. 3 uses are
//! substituted (flagged in the output). Throughput always comes from the
//! simulator.
//!
//! ```bash
//! cargo run --release --example accuracy_frontier -- --batch 16
//! ```

use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::report::{dominates, fig3_table, Fig3Point};
use s4::sim::{simulate, Target};
use s4::util::cli::Args;
use s4::util::json::Json;

/// Published reference accuracies (paper Fig. 3's axes): dense top-1 /
/// GLUE-avg, with the small per-sparsity decay the paper's §4 methods
/// achieve (sparse pruning loses ≈1% at 16x on over-parameterized models).
fn fallback_accuracy(model: &str, sparsity: usize) -> f64 {
    let dense: f64 = match model {
        "resnet50" => 0.761,
        "resnet152" => 0.783,
        "bert_base" => 0.781,
        "bert_large" => 0.805,
        _ => 0.75,
    };
    // decay grows with sparsity, gentler for larger models
    let size_relief = match model {
        "resnet152" | "bert_large" => 0.5,
        _ => 1.0,
    };
    let decay = match sparsity {
        1 => 0.0,
        2 => 0.002,
        4 => 0.004,
        8 => 0.008,
        16 => 0.014,
        _ => 0.030,
    };
    dense - decay * size_relief
}

fn measured_accuracy(path: &std::path::Path) -> Option<Vec<(String, usize, f64)>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    Some(
        j.get("points")
            .as_arr()?
            .iter()
            .filter_map(|p| {
                Some((
                    p.get("model").as_str()?.to_string(),
                    p.get("sparsity").as_u64()? as usize,
                    p.get("accuracy").as_f64()?,
                ))
            })
            .collect(),
    )
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let batch = args.get_usize("batch", 16)?;
    let cfg = AntoumConfig::s4();

    let acc_path = s4::runtime::default_artifact_dir().join("accuracy.json");
    let measured = measured_accuracy(&acc_path);
    match &measured {
        Some(pts) => println!(
            "(accuracy: measured on proxy tasks — {} points from {})",
            pts.len(),
            acc_path.display()
        ),
        None => println!(
            "(accuracy: published reference values — run `python -m compile.train \
             --fig3` for measured proxy accuracies)"
        ),
    }

    let mut points = Vec::new();
    for (name, proxy) in [
        ("resnet50", "bert_proxy_small"),
        ("resnet152", "bert_proxy_large"),
        ("bert_base", "bert_proxy_small"),
        ("bert_large", "bert_proxy_large"),
    ] {
        let g = models::by_name(name, batch)?;
        // dense on T4
        let t4 = simulate(&g, Target::t4());
        points.push(Fig3Point {
            model: name.into(),
            platform: "T4".into(),
            sparsity: 1,
            accuracy: fallback_accuracy(name, 1),
            throughput: t4.throughput,
        });
        // sparse on S4 at the paper's sweep
        for &s in &[1usize, 2, 4, 8, 16] {
            let r = simulate(&g, Target::antoum(&cfg, s));
            // proxy-measured relative decay applied to the published dense
            // point, when available
            let acc = match &measured {
                Some(pts) => {
                    let dense = pts
                        .iter()
                        .find(|(m, sp, _)| m == proxy && *sp == 1)
                        .map(|&(_, _, a)| a);
                    let at_s = pts
                        .iter()
                        .find(|(m, sp, _)| m == proxy && *sp == s)
                        .map(|&(_, _, a)| a);
                    match (dense, at_s) {
                        (Some(d), Some(a)) if d > 0.0 => {
                            fallback_accuracy(name, 1) * (a / d)
                        }
                        _ => fallback_accuracy(name, s),
                    }
                }
                None => fallback_accuracy(name, s),
            };
            points.push(Fig3Point {
                model: name.into(),
                platform: "S4".into(),
                sparsity: s,
                accuracy: acc,
                throughput: r.throughput,
            });
        }
    }
    print!("{}", fig3_table(&points));

    // The paper's insight, verified on the generated frontier:
    println!("\ndominance checks (larger-sparse vs smaller-dense):");
    for (big, small) in [("resnet152", "resnet50"), ("bert_large", "bert_base")] {
        let dense_small = points
            .iter()
            .find(|p| p.model == small && p.platform == "T4")
            .unwrap();
        let best_sparse_big = points
            .iter()
            .filter(|p| p.model == big && p.platform == "S4")
            .filter(|p| p.accuracy >= dense_small.accuracy)
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap());
        match best_sparse_big {
            Some(p) if dominates(p, dense_small) => println!(
                "  {big} (s={}) on S4 DOMINATES {small} dense on T4: \
                 {:+.1}% acc, {:.1}x throughput",
                p.sparsity,
                100.0 * (p.accuracy - dense_small.accuracy),
                p.throughput / dense_small.throughput
            ),
            _ => println!("  {big}: no dominating sparse point (unexpected)"),
        }
    }
    Ok(())
}
