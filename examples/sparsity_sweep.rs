//! Figure 2 driver: speedup vs sparsity for ResNet-50 and BERT-base on the
//! Antoum model, with the T4 dense reference line — prints the same series
//! the paper plots and optionally writes JSON for plotting.
//!
//! ```bash
//! cargo run --release --example sparsity_sweep -- --batch 16 [--json out.json] [--event]
//! ```

use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::{report, simulate, simulate_event, Parallelism, Target};
use s4::sparse::tensor::DType;
use s4::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let batch = args.get_usize("batch", 16)?;
    let sparsities = args.get_usize_list("sparsities", &[1, 2, 4, 8, 16, 32])?;
    let cfg = AntoumConfig::s4();

    let resnet = models::resnet50(batch, 224);
    let bert = models::bert(models::BERT_BASE, batch, 128);

    let tput = |g: &s4::graph::Graph, s: usize| -> f64 {
        if args.has("event") {
            simulate_event(g, &cfg, s, DType::Int8, Parallelism::DataParallel).throughput
        } else {
            simulate(g, Target::antoum(&cfg, s)).throughput
        }
    };

    let base_r = tput(&resnet, 1);
    let base_b = tput(&bert, 1);
    let mut rows = Vec::new();
    for &s in &sparsities {
        let tr = tput(&resnet, s);
        let tb = tput(&bert, s);
        rows.push(report::Fig2Row {
            sparsity: s,
            resnet50_tput: tr,
            resnet50_speedup: tr / base_r,
            bert_tput: tb,
            bert_speedup: tb / base_b,
        });
    }
    let t4r = simulate(&resnet, Target::t4()).throughput;
    let t4b = simulate(&bert, Target::t4()).throughput;
    print!("{}", report::fig2_table(&rows, t4r, t4b));

    // the paper's prose claims, checked at runtime:
    let last = rows.last().unwrap();
    println!();
    println!(
        "ResNet50 @32x: {:.1}x ({} almost linear)   BERT @32x: {:.1}x (sublinear — \
         {:.1}% of FLOPs are non-sparsifiable)",
        last.resnet50_speedup,
        if last.resnet50_speedup > 22.0 { "✓" } else { "✗" },
        last.bert_speedup,
        100.0 * (1.0 - bert.sparsifiable_fraction()),
    );

    if let Some(path) = args.get("json") {
        std::fs::write(path, report::fig2_json(&rows, t4r, t4b).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
