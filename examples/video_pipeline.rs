//! Vision pipeline driver: codec engines → SPU inference (paper §2's
//! multimedia claims: 64× 1080p30 video decode, 2320 FPS JPEG decode,
//! "a complete end-to-end solution for video and image inference").
//!
//! Simulates N camera streams decoded on the video engines, frames resized
//! and batched into ResNet-50 inference on the SPUs; reports the pipeline
//! bottleneck at each sparsity. Shows the §2 sizing logic: at low sparsity
//! the SPUs bottleneck the pipeline; at 8x+ the codec becomes the limit —
//! exactly why a 70 W inference card wants this much decode capability.
//!
//! ```bash
//! cargo run --release --example video_pipeline -- --streams 64 --fps 30
//! ```

use s4::arch::codec::{FrameSpec, JpegDecoder, VideoDecoder};
use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::{simulate, Target};
use s4::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let streams = args.get_usize("streams", 64)?;
    let fps = args.get_f64("fps", 30.0)?;
    let batch = args.get_usize("batch", 16)?;
    let cfg = AntoumConfig::s4();

    let video = VideoDecoder::from_config(&cfg);
    let jpeg = JpegDecoder::from_config(&cfg);

    println!("codec capability:");
    println!(
        "  video: {} concurrent 1080p30 streams ({} engines)",
        video.max_streams(FrameSpec::FHD, 30.0),
        video.engines
    );
    println!(
        "  jpeg:  {:.0} FPS @1080p ({:.0} FPS @4K)",
        jpeg.throughput(FrameSpec::FHD),
        jpeg.throughput(FrameSpec::UHD4K)
    );

    let per_stream = video.per_stream_fps(streams, FrameSpec::FHD, fps);
    let decode_fps = per_stream * streams as f64;
    println!(
        "\nworkload: {streams} streams @ {fps} fps requested → decode sustains \
         {per_stream:.1} fps/stream ({decode_fps:.0} frames/s total)"
    );

    println!("\npipeline throughput (frames/s), ResNet-50 on SPUs:");
    println!(
        "{:>8} | {:>12} | {:>12} | {:>12} | {}",
        "sparsity", "decode f/s", "infer f/s", "pipeline f/s", "bottleneck"
    );
    let g = models::resnet50(batch, 224);
    for s in [1usize, 2, 4, 8, 16, 32] {
        let infer = simulate(&g, Target::antoum(&cfg, s)).throughput;
        let pipeline = decode_fps.min(infer);
        let bottleneck = if infer < decode_fps { "SPU inference" } else { "video decode" };
        println!(
            "{:>8} | {:>12.0} | {:>12.0} | {:>12.0} | {}",
            s, decode_fps, infer, pipeline, bottleneck
        );
    }

    // JPEG path: still-image serving (e.g. photo moderation)
    println!("\nJPEG still-image path (1080p):");
    let jfps = jpeg.throughput(FrameSpec::FHD);
    for s in [1usize, 8, 32] {
        let infer = simulate(&g, Target::antoum(&cfg, s)).throughput;
        println!(
            "  s={s:<2}: min(decode {:.0}, infer {:.0}) = {:.0} img/s",
            jfps,
            infer,
            jfps.min(infer)
        );
    }
    Ok(())
}
